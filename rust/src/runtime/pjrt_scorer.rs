//! [`PjrtScorer`] — a [`ScoreBackend`] whose compute runs inside the
//! AOT-compiled XLA executables (L2 JAX model + L1 Pallas kernels).
//!
//! Fixed-shape discipline: every executable was lowered for `[B, d]`
//! blocks. Calls with fewer than `B` rows are zero-padded and masked via
//! the `count` input (fused kernels) or sliced on the host (`scores`).
//! Larger inputs are chunked. One compiled executable per entry point,
//! reused for the life of the process — no per-call compilation anywhere.
//!
//! ## Thread safety
//!
//! The `xla` crate's PJRT wrappers hold `Rc` internals and raw pointers,
//! so they are neither `Send` nor `Sync`. We serialize **all** access
//! (execution, literal construction tied to the client, and eventual
//! drop) behind one `Mutex`, never hand out references to the inner
//! state, and only then assert `Send + Sync`. The PJRT CPU client itself
//! is thread-compatible under external synchronization. Workers that
//! need parallel XLA compute should each own their own `PjrtScorer`
//! (each gets its own PJRT client).

use super::client::{literal_f32, literal_i32, Runtime};
use crate::error::Result;
use crate::linalg::MaxSumExp;
use crate::scorer::ScoreBackend;
use std::sync::Mutex;

struct Inner {
    rt: Runtime,
    /// staging buffer for padded blocks
    stage: Vec<f32>,
}

/// PJRT-backed scorer. All XLA access is serialized internally.
pub struct PjrtScorer {
    inner: Mutex<Inner>,
    block: usize,
    d: usize,
}

// SAFETY: see module docs — every touch of the non-Send internals happens
// under `self.inner`'s mutex, including Drop (the scorer is dropped on
// whichever thread holds the last Arc, with no concurrent access by
// construction).
unsafe impl Send for PjrtScorer {}
// SAFETY: same serialization argument as Send — `&PjrtScorer` exposes the
// inner state only through the mutex, so shared references never touch
// the thread-incompatible internals concurrently.
unsafe impl Sync for PjrtScorer {}

impl PjrtScorer {
    /// Wrap a loaded runtime. Fails fast if the required entries are
    /// missing.
    pub fn new(rt: Runtime) -> Result<Self> {
        for name in ["scores", "partition", "expect"] {
            rt.executable(name)?;
        }
        let block = rt.manifest.block;
        let d = rt.manifest.d;
        Ok(PjrtScorer {
            inner: Mutex::new(Inner { rt, stage: vec![0f32; block * d] }),
            block,
            d,
        })
    }

    /// Load artifacts from a directory and wrap them.
    pub fn load(dir: &str) -> Result<Self> {
        Self::new(Runtime::load(dir)?)
    }

    pub fn block(&self) -> usize {
        self.block
    }

    pub fn d(&self) -> usize {
        self.d
    }

    fn with_inner<T>(&self, f: impl FnOnce(&mut Inner) -> Result<T>) -> Result<T> {
        // recover from poisoning: the staging buffer is overwritten from
        // scratch by every call, so a panic mid-call leaves nothing that
        // the next caller could observe
        let mut g = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        f(&mut g)
    }
}

impl Inner {
    fn pad_literal(&mut self, rows: &[f32], block: usize, d: usize) -> Result<xla::Literal> {
        if rows.len() == block * d {
            literal_f32(rows, &[block as i64, d as i64])
        } else {
            self.stage[..rows.len()].copy_from_slice(rows);
            self.stage[rows.len()..].fill(0.0);
            literal_f32(&self.stage, &[block as i64, d as i64])
        }
    }

    fn scores_block(
        &mut self,
        rows: &[f32],
        q: &[f32],
        out: &mut [f32],
        block: usize,
        d: usize,
    ) -> Result<()> {
        let n = out.len();
        let vlit = self.pad_literal(rows, block, d)?;
        let qlit = literal_f32(q, &[d as i64])?;
        let exe = self.rt.executable("scores")?;
        let outs = exe.run(&[vlit, qlit])?;
        let full: Vec<f32> = outs[0].to_vec::<f32>()?;
        out.copy_from_slice(&full[..n]);
        Ok(())
    }

    fn partition_block(
        &mut self,
        rows: &[f32],
        q: &[f32],
        count: usize,
        block: usize,
        d: usize,
    ) -> Result<MaxSumExp> {
        let vlit = self.pad_literal(rows, block, d)?;
        let qlit = literal_f32(q, &[d as i64])?;
        let exe = self.rt.executable("partition")?;
        let outs = exe.run(&[vlit, qlit, literal_i32(count as i32)])?;
        let max = outs[0].to_vec::<f32>()?[0] as f64;
        let sumexp = outs[1].to_vec::<f32>()?[0] as f64;
        Ok(MaxSumExp { max, sumexp, count: count as u64 })
    }

    fn expect_block(
        &mut self,
        rows: &[f32],
        q: &[f32],
        count: usize,
        block: usize,
        d: usize,
    ) -> Result<(MaxSumExp, Vec<f32>)> {
        let vlit = self.pad_literal(rows, block, d)?;
        let qlit = literal_f32(q, &[d as i64])?;
        let exe = self.rt.executable("expect")?;
        let outs = exe.run(&[vlit, qlit, literal_i32(count as i32)])?;
        let max = outs[0].to_vec::<f32>()?[0] as f64;
        let sumexp = outs[1].to_vec::<f32>()?[0] as f64;
        let wsum = outs[2].to_vec::<f32>()?;
        Ok((MaxSumExp { max, sumexp, count: count as u64 }, wsum))
    }
}

impl ScoreBackend for PjrtScorer {
    fn scores(&self, rows: &[f32], d: usize, q: &[f32], out: &mut [f32]) {
        assert_eq!(d, self.d, "PjrtScorer compiled for d={}, got {d}", self.d);
        let n = out.len();
        let block = self.block;
        self.with_inner(|inner| {
            let mut start = 0;
            while start < n {
                let end = (start + block).min(n);
                inner.scores_block(&rows[start * d..end * d], q, &mut out[start..end], block, d)?;
                start = end;
            }
            Ok(())
        })
        .expect("PJRT scores execution failed");
    }

    fn max_sumexp(&self, rows: &[f32], d: usize, q: &[f32]) -> MaxSumExp {
        assert_eq!(d, self.d);
        let n = rows.len() / d;
        let block = self.block;
        self.with_inner(|inner| {
            let mut acc = MaxSumExp::default();
            let mut start = 0;
            while start < n {
                let end = (start + block).min(n);
                let frag =
                    inner.partition_block(&rows[start * d..end * d], q, end - start, block, d)?;
                acc.merge(&frag);
                start = end;
            }
            Ok(acc)
        })
        .expect("PJRT partition execution failed")
    }

    fn expect_fragment(&self, rows: &[f32], d: usize, q: &[f32]) -> (MaxSumExp, Vec<f32>) {
        assert_eq!(d, self.d);
        let n = rows.len() / d;
        let block = self.block;
        let frags = self
            .with_inner(|inner| {
                let mut frags = Vec::new();
                let mut start = 0;
                while start < n {
                    let end = (start + block).min(n);
                    frags.push(inner.expect_block(
                        &rows[start * d..end * d],
                        q,
                        end - start,
                        block,
                        d,
                    )?);
                    start = end;
                }
                Ok(frags)
            })
            .expect("PJRT expect execution failed");
        crate::scorer::merge_expect_fragments(&frags, d)
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}

// Integration tests against real artifacts live in rust/tests/ — they
// require `make artifacts` to have produced artifacts/ first.
