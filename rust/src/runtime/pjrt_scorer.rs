//! [`PjrtScorer`] — a [`ScoreBackend`] whose compute runs inside the
//! AOT-compiled XLA executables (L2 JAX model + L1 Pallas kernels).
//!
//! Fixed-shape discipline: every executable was lowered for `[B, d]`
//! blocks. Calls with fewer than `B` rows are zero-padded and masked via
//! the `count` input (fused kernels) or sliced on the host (`scores`).
//! Larger inputs are chunked. One compiled executable per entry point,
//! reused for the life of the process — no per-call compilation anywhere.
//!
//! Batched artifact sets (PR 10) additionally carry `scores_batch` /
//! `partition_batch` / `expect_batch` over `[Q × d]` query groups and an
//! integer `sq8_screen` entry (see `python/compile/aot.py`). This scorer
//! derives the group size `Q` from the `scores_batch` entry's input
//! shapes and overrides [`ScoreBackend::scores_batch`] to cross the
//! device boundary once per query group instead of once per query;
//! without the entry (older artifacts) it falls back to the per-query
//! loop. The remaining batched entries are lowered and validated by the
//! Python-side tests, ready for fused batch estimation to adopt.
//!
//! ## Thread safety
//!
//! The `xla` crate's PJRT wrappers hold `Rc` internals and raw pointers,
//! so they are neither `Send` nor `Sync`. We serialize **all** access
//! (execution, literal construction tied to the client, and eventual
//! drop) behind one `Mutex`, never hand out references to the inner
//! state, and only then assert `Send + Sync`. The PJRT CPU client itself
//! is thread-compatible under external synchronization. Workers that
//! need parallel XLA compute should each own their own `PjrtScorer`
//! (each gets its own PJRT client).

use super::client::{literal_f32, literal_i32, Runtime};
use crate::error::Result;
use crate::linalg::MaxSumExp;
use crate::scorer::ScoreBackend;
use std::sync::Mutex;

struct Inner {
    rt: Runtime,
    /// staging buffer for padded blocks
    stage: Vec<f32>,
    /// staging buffer for padded query groups (`qbatch × d`)
    qstage: Vec<f32>,
}

/// PJRT-backed scorer. All XLA access is serialized internally.
pub struct PjrtScorer {
    inner: Mutex<Inner>,
    block: usize,
    d: usize,
    /// query-group size of the batched executables, derived from the
    /// `scores_batch` entry's input shapes; `None` with older artifact
    /// sets (batched calls fall back to the per-query executable loop)
    qbatch: Option<usize>,
}

// SAFETY: see module docs — every touch of the non-Send internals happens
// under `self.inner`'s mutex, including Drop (the scorer is dropped on
// whichever thread holds the last Arc, with no concurrent access by
// construction).
unsafe impl Send for PjrtScorer {}
// SAFETY: same serialization argument as Send — `&PjrtScorer` exposes the
// inner state only through the mutex, so shared references never touch
// the thread-incompatible internals concurrently.
unsafe impl Sync for PjrtScorer {}

impl PjrtScorer {
    /// Wrap a loaded runtime. Fails fast if the required entries are
    /// missing.
    pub fn new(rt: Runtime) -> Result<Self> {
        for name in ["scores", "partition", "expect"] {
            rt.executable(name)?;
        }
        let block = rt.manifest.block;
        let d = rt.manifest.d;
        // Batched entries are optional: their presence (and the query
        // group size) is read off the manifest shapes, so older artifact
        // directories load unchanged and simply skip the batched path.
        let qbatch = match rt.manifest.entry("scores_batch") {
            Some(e) if rt.executable("scores_batch").is_ok() => {
                e.inputs.get(1).and_then(|s| s.first()).copied().filter(|&q| q > 0)
            }
            _ => None,
        };
        let qstage = vec![0f32; qbatch.unwrap_or(0) * d];
        Ok(PjrtScorer {
            inner: Mutex::new(Inner { rt, stage: vec![0f32; block * d], qstage }),
            block,
            d,
            qbatch,
        })
    }

    /// Load artifacts from a directory and wrap them.
    pub fn load(dir: &str) -> Result<Self> {
        Self::new(Runtime::load(dir)?)
    }

    pub fn block(&self) -> usize {
        self.block
    }

    pub fn d(&self) -> usize {
        self.d
    }

    fn with_inner<T>(&self, f: impl FnOnce(&mut Inner) -> Result<T>) -> Result<T> {
        // recover from poisoning: the staging buffer is overwritten from
        // scratch by every call, so a panic mid-call leaves nothing that
        // the next caller could observe
        let mut g = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        f(&mut g)
    }
}

impl Inner {
    fn pad_literal(&mut self, rows: &[f32], block: usize, d: usize) -> Result<xla::Literal> {
        if rows.len() == block * d {
            literal_f32(rows, &[block as i64, d as i64])
        } else {
            self.stage[..rows.len()].copy_from_slice(rows);
            self.stage[rows.len()..].fill(0.0);
            literal_f32(&self.stage, &[block as i64, d as i64])
        }
    }

    fn scores_block(
        &mut self,
        rows: &[f32],
        q: &[f32],
        out: &mut [f32],
        block: usize,
        d: usize,
    ) -> Result<()> {
        let n = out.len();
        let vlit = self.pad_literal(rows, block, d)?;
        let qlit = literal_f32(q, &[d as i64])?;
        let exe = self.rt.executable("scores")?;
        let outs = exe.run(&[vlit, qlit])?;
        let full: Vec<f32> = outs[0].to_vec::<f32>()?;
        out.copy_from_slice(&full[..n]);
        Ok(())
    }

    /// One batched-executable call: a (possibly short) row block scored
    /// for a (possibly short) query group. Returns the full query-major
    /// `[qb × block]` output; the caller slices out the live region.
    fn scores_batch_block(
        &mut self,
        rows: &[f32],
        qgroup: &[f32],
        block: usize,
        d: usize,
        qb: usize,
    ) -> Result<Vec<f32>> {
        let vlit = self.pad_literal(rows, block, d)?;
        let qslit = if qgroup.len() == qb * d {
            literal_f32(qgroup, &[qb as i64, d as i64])?
        } else {
            self.qstage[..qgroup.len()].copy_from_slice(qgroup);
            self.qstage[qgroup.len()..].fill(0.0);
            literal_f32(&self.qstage, &[qb as i64, d as i64])?
        };
        let exe = self.rt.executable("scores_batch")?;
        let outs = exe.run(&[vlit, qslit])?;
        Ok(outs[0].to_vec::<f32>()?)
    }

    fn partition_block(
        &mut self,
        rows: &[f32],
        q: &[f32],
        count: usize,
        block: usize,
        d: usize,
    ) -> Result<MaxSumExp> {
        let vlit = self.pad_literal(rows, block, d)?;
        let qlit = literal_f32(q, &[d as i64])?;
        let exe = self.rt.executable("partition")?;
        let outs = exe.run(&[vlit, qlit, literal_i32(count as i32)])?;
        let max = outs[0].to_vec::<f32>()?[0] as f64;
        let sumexp = outs[1].to_vec::<f32>()?[0] as f64;
        Ok(MaxSumExp { max, sumexp, count: count as u64 })
    }

    fn expect_block(
        &mut self,
        rows: &[f32],
        q: &[f32],
        count: usize,
        block: usize,
        d: usize,
    ) -> Result<(MaxSumExp, Vec<f32>)> {
        let vlit = self.pad_literal(rows, block, d)?;
        let qlit = literal_f32(q, &[d as i64])?;
        let exe = self.rt.executable("expect")?;
        let outs = exe.run(&[vlit, qlit, literal_i32(count as i32)])?;
        let max = outs[0].to_vec::<f32>()?[0] as f64;
        let sumexp = outs[1].to_vec::<f32>()?[0] as f64;
        let wsum = outs[2].to_vec::<f32>()?;
        Ok((MaxSumExp { max, sumexp, count: count as u64 }, wsum))
    }
}

impl ScoreBackend for PjrtScorer {
    fn scores(&self, rows: &[f32], d: usize, q: &[f32], out: &mut [f32]) {
        assert_eq!(d, self.d, "PjrtScorer compiled for d={}, got {d}", self.d);
        let n = out.len();
        let block = self.block;
        self.with_inner(|inner| {
            let mut start = 0;
            while start < n {
                let end = (start + block).min(n);
                inner.scores_block(&rows[start * d..end * d], q, &mut out[start..end], block, d)?;
                start = end;
            }
            Ok(())
        })
        .expect("PJRT scores execution failed");
    }

    /// Batched scoring through the `scores_batch` executable: each row
    /// block crosses the device boundary once per query *group* (the
    /// manifest's `qbatch`) instead of once per query — the same
    /// amortization the register-blocked native kernels get on the CPU.
    /// Artifact sets without the batched entry fall back to the
    /// per-query loop, so old artifacts keep working unchanged.
    fn scores_batch(&self, rows: &[f32], d: usize, qs: &[f32], nq: usize, out: &mut [f32]) {
        assert_eq!(d, self.d, "PjrtScorer compiled for d={}, got {d}", self.d);
        let nrows = if d == 0 { 0 } else { rows.len() / d };
        debug_assert_eq!(qs.len(), nq * d);
        debug_assert_eq!(out.len(), nq * nrows);
        let Some(qb) = self.qbatch else {
            for j in 0..nq {
                let (qj, oj) = (&qs[j * d..(j + 1) * d], &mut out[j * nrows..(j + 1) * nrows]);
                self.scores(rows, d, qj, oj);
            }
            return;
        };
        let block = self.block;
        self.with_inner(|inner| {
            for j0 in (0..nq).step_by(qb) {
                let j1 = (j0 + qb).min(nq);
                let qgroup = &qs[j0 * d..j1 * d];
                let mut start = 0;
                while start < nrows {
                    let end = (start + block).min(nrows);
                    let full =
                        inner.scores_batch_block(&rows[start * d..end * d], qgroup, block, d, qb)?;
                    for g in 0..j1 - j0 {
                        let dst = (j0 + g) * nrows + start;
                        out[dst..dst + (end - start)]
                            .copy_from_slice(&full[g * block..g * block + (end - start)]);
                    }
                    start = end;
                }
            }
            Ok(())
        })
        .expect("PJRT batched scores execution failed");
    }

    fn max_sumexp(&self, rows: &[f32], d: usize, q: &[f32]) -> MaxSumExp {
        assert_eq!(d, self.d);
        let n = rows.len() / d;
        let block = self.block;
        self.with_inner(|inner| {
            let mut acc = MaxSumExp::default();
            let mut start = 0;
            while start < n {
                let end = (start + block).min(n);
                let frag =
                    inner.partition_block(&rows[start * d..end * d], q, end - start, block, d)?;
                acc.merge(&frag);
                start = end;
            }
            Ok(acc)
        })
        .expect("PJRT partition execution failed")
    }

    fn expect_fragment(&self, rows: &[f32], d: usize, q: &[f32]) -> (MaxSumExp, Vec<f32>) {
        assert_eq!(d, self.d);
        let n = rows.len() / d;
        let block = self.block;
        let frags = self
            .with_inner(|inner| {
                let mut frags = Vec::new();
                let mut start = 0;
                while start < n {
                    let end = (start + block).min(n);
                    frags.push(inner.expect_block(
                        &rows[start * d..end * d],
                        q,
                        end - start,
                        block,
                        d,
                    )?);
                    start = end;
                }
                Ok(frags)
            })
            .expect("PJRT expect execution failed");
        crate::scorer::merge_expect_fragments(&frags, d)
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}

// Integration tests against real artifacts live in rust/tests/ — they
// require `make artifacts` to have produced artifacts/ first.
