//! PJRT artifact runtime — the bridge from AOT-compiled JAX/Pallas compute
//! to the Rust hot path.
//!
//! `make artifacts` runs `python/compile/aot.py`, which lowers the L2 JAX
//! model (calling the L1 Pallas kernels) to **HLO text** files plus a
//! `manifest.json` describing every entry point's shapes. This module
//! loads the manifest, compiles each HLO module once on the PJRT CPU
//! client (`xla` crate ↔ xla_extension 0.5.1), and exposes typed
//! executable wrappers.
//!
//! HLO *text* is the interchange format: jax ≥ 0.5 emits protos with
//! 64-bit instruction ids which this XLA build rejects; the text parser
//! reassigns ids (see /opt/xla-example/README.md).

use crate::error::{Error, Result};
use crate::util::json::Json;
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// One AOT entry point as described by the manifest.
#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    pub name: String,
    pub file: String,
    /// input shapes (row-major dims)
    pub inputs: Vec<Vec<usize>>,
    /// output shapes
    pub outputs: Vec<Vec<usize>>,
}

/// Parsed `artifacts/manifest.json`.
#[derive(Clone, Debug)]
pub struct ArtifactManifest {
    pub block: usize,
    pub d: usize,
    pub entries: Vec<ArtifactEntry>,
    pub dir: PathBuf,
}

impl ArtifactManifest {
    /// Load and validate `dir/manifest.json`.
    pub fn load<P: AsRef<Path>>(dir: P) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            Error::runtime(format!(
                "cannot read {} — run `make artifacts` first ({e})",
                path.display()
            ))
        })?;
        let j = Json::parse(&text)?;
        let block = j.req("block")?.as_usize()?;
        let d = j.req("d")?.as_usize()?;
        let mut entries = Vec::new();
        for e in j.req("entries")?.as_arr()? {
            let shapes = |key: &str| -> Result<Vec<Vec<usize>>> {
                e.req(key)?
                    .as_arr()?
                    .iter()
                    .map(|s| s.as_usize_vec())
                    .collect()
            };
            entries.push(ArtifactEntry {
                name: e.req("name")?.as_str()?.to_string(),
                file: e.req("file")?.as_str()?.to_string(),
                inputs: shapes("inputs")?,
                outputs: shapes("outputs")?,
            });
        }
        let m = ArtifactManifest { block, d, entries, dir };
        m.validate()?;
        Ok(m)
    }

    fn validate(&self) -> Result<()> {
        if self.block == 0 || self.d == 0 {
            return Err(Error::runtime("manifest block/d must be positive"));
        }
        for e in &self.entries {
            if !self.dir.join(&e.file).exists() {
                return Err(Error::runtime(format!(
                    "manifest references missing artifact file {}",
                    e.file
                )));
            }
        }
        Ok(())
    }

    pub fn entry(&self, name: &str) -> Option<&ArtifactEntry> {
        self.entries.iter().find(|e| e.name == name)
    }
}

/// A compiled PJRT executable with its manifest entry.
pub struct Executable {
    pub entry: ArtifactEntry,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute with literal inputs, returning the flattened tuple outputs.
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let bufs = self.exe.execute::<xla::Literal>(inputs)?;
        let lit = bufs[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True
        Ok(lit.to_tuple()?)
    }
}

/// PJRT CPU client owning compiled executables for every artifact entry.
pub struct Runtime {
    pub manifest: ArtifactManifest,
    executables: HashMap<String, Executable>,
    pub platform: String,
}

impl Runtime {
    /// Load all artifacts from a directory and compile them.
    pub fn load<P: AsRef<Path>>(dir: P) -> Result<Self> {
        let manifest = ArtifactManifest::load(dir)?;
        let client = xla::PjRtClient::cpu()?;
        let platform = client.platform_name();
        let mut executables = HashMap::new();
        for entry in &manifest.entries {
            let path = manifest.dir.join(&entry.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str()
                    .ok_or_else(|| Error::runtime("non-utf8 artifact path"))?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp)?;
            executables.insert(entry.name.clone(), Executable { entry: entry.clone(), exe });
            eprintln!("compiled artifact '{}' from {}", entry.name, entry.file);
        }
        Ok(Runtime { manifest, executables, platform })
    }

    pub fn executable(&self, name: &str) -> Result<&Executable> {
        self.executables
            .get(name)
            .ok_or_else(|| Error::runtime(format!("no artifact entry named '{name}'")))
    }

    pub fn names(&self) -> Vec<&str> {
        self.executables.keys().map(|s| s.as_str()).collect()
    }
}

/// Build an `f32` literal of the given dims from a slice.
pub fn literal_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    let expected: i64 = dims.iter().product();
    if expected as usize != data.len() {
        return Err(Error::runtime(format!(
            "literal shape {dims:?} incompatible with {} elements",
            data.len()
        )));
    }
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

/// Scalar i32 literal (block-mask `count` inputs).
pub fn literal_i32(v: i32) -> xla::Literal {
    xla::Literal::scalar(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, body: &str) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.json"), body).unwrap();
    }

    #[test]
    fn manifest_parses() {
        let dir = std::env::temp_dir().join(format!("gmips_art_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("scores.hlo.txt"), "HloModule m").unwrap();
        write_manifest(
            &dir,
            r#"{"block":128,"d":16,"entries":[
                {"name":"scores","file":"scores.hlo.txt",
                 "inputs":[[128,16],[16]],"outputs":[[128]]}]}"#,
        );
        let m = ArtifactManifest::load(&dir).unwrap();
        assert_eq!(m.block, 128);
        assert_eq!(m.d, 16);
        let e = m.entry("scores").unwrap();
        assert_eq!(e.inputs[0], vec![128, 16]);
        assert!(m.entry("nope").is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn manifest_missing_file_rejected() {
        let dir = std::env::temp_dir().join(format!("gmips_art2_{}", std::process::id()));
        write_manifest(
            &dir,
            r#"{"block":128,"d":16,"entries":[
                {"name":"x","file":"missing.hlo.txt","inputs":[],"outputs":[]}]}"#,
        );
        assert!(ArtifactManifest::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn manifest_absent_gives_helpful_error() {
        let err = ArtifactManifest::load("/definitely/not/here").unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }

    #[test]
    fn literal_shape_check() {
        assert!(literal_f32(&[1.0, 2.0], &[3]).is_err());
        let l = literal_f32(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        assert_eq!(l.element_count(), 4);
    }
}
