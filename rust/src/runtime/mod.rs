//! PJRT runtime: load the AOT artifacts emitted by `python/compile/aot.py`
//! (HLO text + manifest), compile them once, and expose a
//! [`ScoreBackend`](crate::scorer::ScoreBackend) that runs the paper's
//! score/partition/expectation compute inside XLA.

pub mod client;
pub mod pjrt_scorer;

pub use client::{ArtifactManifest, Runtime};
pub use pjrt_scorer::PjrtScorer;
