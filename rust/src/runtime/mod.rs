//! PJRT runtime: load the AOT artifacts emitted by `python/compile/aot.py`
//! (HLO text + manifest), compile them once, and expose a
//! [`ScoreBackend`](crate::scorer::ScoreBackend) that runs the paper's
//! score/partition/expectation compute inside XLA.
//!
//! ## Feature gating
//!
//! The real implementation needs the vendored `xla` crate, which the
//! offline registry does not carry, so it sits behind the off-by-default
//! `pjrt` cargo feature. Without the feature this module exports a
//! [stub `PjrtScorer`](stub) with the same surface whose `load` fails
//! gracefully at runtime — every artifact-dependent caller (CLI
//! `selfcheck`, integration tests, benches) keeps compiling and degrades
//! to "artifacts unavailable" behavior.
//!
//! Enabling the feature takes two steps, both deliberate: add the
//! vendored crate under `[dependencies]` (`xla = { path = ... }` — it is
//! not declared as an optional dependency because cargo resolves even
//! unused optional deps, which would break the offline default build)
//! and pass `--features pjrt`.

#[cfg(feature = "pjrt")]
pub mod client;
#[cfg(feature = "pjrt")]
pub mod pjrt_scorer;

#[cfg(feature = "pjrt")]
pub use client::{ArtifactManifest, Runtime};
#[cfg(feature = "pjrt")]
pub use pjrt_scorer::PjrtScorer;

#[cfg(not(feature = "pjrt"))]
pub mod stub;
#[cfg(not(feature = "pjrt"))]
pub use stub::PjrtScorer;
