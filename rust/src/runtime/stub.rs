//! Stub [`PjrtScorer`] for builds without the `pjrt` cargo feature.
//!
//! The XLA/PJRT backend needs the vendored `xla` crate, which the offline
//! registry does not provide. This stub keeps the public surface of the
//! real scorer (`load`, `block`, `d`, the [`ScoreBackend`] impl) so every
//! caller compiles unchanged, but `load` — the only constructor — always
//! returns a runtime error. The type is therefore unconstructible in
//! stub builds and the remaining methods are statically unreachable.

use crate::error::{Error, Result};
use crate::scorer::ScoreBackend;

/// Placeholder for the PJRT-backed scorer. See the module docs: in
/// builds without the `pjrt` feature this cannot be constructed.
pub struct PjrtScorer {
    _unconstructible: std::convert::Infallible,
}

impl PjrtScorer {
    /// Always fails: this build does not include the XLA/PJRT runtime.
    pub fn load(_dir: &str) -> Result<Self> {
        Err(Error::runtime(
            "built without the `pjrt` cargo feature — rebuild with `--features pjrt` \
             (requires the vendored `xla` crate) to load AOT artifacts",
        ))
    }

    /// AOT block size (unreachable: the stub cannot be constructed).
    pub fn block(&self) -> usize {
        match self._unconstructible {}
    }

    /// Compiled feature dimension (unreachable: see [`block`](Self::block)).
    pub fn d(&self) -> usize {
        match self._unconstructible {}
    }
}

impl ScoreBackend for PjrtScorer {
    fn scores(&self, _rows: &[f32], _d: usize, _q: &[f32], _out: &mut [f32]) {
        match self._unconstructible {}
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_fails_gracefully() {
        let err = match PjrtScorer::load("artifacts") {
            Err(e) => e,
            Ok(_) => panic!("stub must not load"),
        };
        assert!(err.to_string().contains("pjrt"), "{err}");
    }
}
