//! TCP front-end: JSON-lines protocol over `std::net` (the offline
//! registry has no tokio; a thread-per-connection accept loop feeding the
//! coordinator's bounded queue gives the same backpressure semantics).
//!
//! Wire format: one JSON object per line, request → response
//! (see [`crate::coordinator::api`]). `{"op":"shutdown"}` stops the
//! server (used by tests and the CLI's `--oneshot` mode).

use crate::coordinator::{api::Request, Coordinator, Response};
use crate::error::{Error, Result};
use crate::util::json::Json;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// Blocking JSON-lines server.
pub struct Server {
    coordinator: Arc<Coordinator>,
    listener: TcpListener,
    stop: Arc<AtomicBool>,
}

impl Server {
    /// Bind to `addr` (e.g. `127.0.0.1:7431`; port 0 picks a free port).
    pub fn bind(coordinator: Arc<Coordinator>, addr: &str) -> Result<Server> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| Error::serve(format!("cannot bind {addr}: {e}")))?;
        Ok(Server { coordinator, listener, stop: Arc::new(AtomicBool::new(false)) })
    }

    /// The actually-bound address.
    pub fn local_addr(&self) -> Result<String> {
        Ok(self.listener.local_addr().map_err(Error::Io)?.to_string())
    }

    /// Serve until a `shutdown` op arrives. Each connection gets its own
    /// thread; requests within a connection are processed in order.
    pub fn serve(&self) -> Result<()> {
        // polling accept so the stop flag is honoured promptly
        self.listener.set_nonblocking(true).map_err(Error::Io)?;
        let mut conns: Vec<std::thread::JoinHandle<()>> = Vec::new();
        while !self.stop.load(Ordering::SeqCst) {
            match self.listener.accept() {
                Ok((stream, _)) => {
                    let coord = self.coordinator.clone();
                    let stop = self.stop.clone();
                    conns.push(std::thread::spawn(move || {
                        let _ = handle_conn(stream, &coord, &stop);
                    }));
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(std::time::Duration::from_millis(5));
                }
                Err(e) => return Err(Error::Io(e)),
            }
        }
        for c in conns {
            let _ = c.join();
        }
        Ok(())
    }

    /// Handle for stopping from another thread.
    pub fn stop_flag(&self) -> Arc<AtomicBool> {
        self.stop.clone()
    }
}

fn handle_conn(stream: TcpStream, coord: &Coordinator, stop: &AtomicBool) -> Result<()> {
    stream.set_nodelay(true).ok();
    // A blocking `reader.lines()` loop would pin this thread inside
    // `read` for as long as the client keeps the connection open but
    // idle — `serve()`'s final `join` would then never return after a
    // shutdown requested on *another* connection. Poll with a short read
    // timeout instead so the stop flag is honoured promptly.
    stream
        .set_read_timeout(Some(std::time::Duration::from_millis(25)))
        .map_err(Error::Io)?;
    let mut reader = BufReader::new(stream.try_clone().map_err(Error::Io)?);
    let mut writer = BufWriter::new(stream);
    // Raw byte buffer, NOT read_line: `read_until` appends whatever was
    // read even when it errors, so a request split across the timeout
    // boundary is completed by the next iteration — read_line would
    // discard already-consumed bytes whenever the partial read ends
    // mid-way through a multibyte UTF-8 character, desynchronizing the
    // framing.
    let mut buf: Vec<u8> = Vec::new();
    loop {
        if stop.load(Ordering::SeqCst) {
            return Ok(());
        }
        match reader.read_until(b'\n', &mut buf) {
            Ok(0) => return Ok(()), // EOF: client went away
            Ok(_) => {}
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                continue;
            }
            Err(e) => return Err(Error::Io(e)),
        }
        let line = String::from_utf8_lossy(&buf);
        if line.trim().is_empty() {
            buf.clear();
            continue;
        }
        let reply = match Json::parse(&line) {
            Err(e) => Response::Error { message: format!("bad json: {e}") },
            Ok(j) => {
                if j.get("op").and_then(|o| o.as_str().ok()) == Some("shutdown") {
                    stop.store(true, Ordering::SeqCst);
                    let msg = Response::Stats { text: "shutting down".into() };
                    writeln!(writer, "{}", msg.to_json().to_string()).map_err(Error::Io)?;
                    writer.flush().map_err(Error::Io)?;
                    return Ok(());
                }
                match Request::from_json(&j) {
                    Err(e) => Response::Error { message: e.to_string() },
                    Ok(req) => match coord.call(req) {
                        Ok(resp) => resp,
                        Err(e) => Response::Error { message: e.to_string() },
                    },
                }
            }
        };
        writeln!(writer, "{}", reply.to_json().to_string()).map_err(Error::Io)?;
        writer.flush().map_err(Error::Io)?;
        buf.clear();
    }
}

/// Blocking JSON-lines client.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr)
            .map_err(|e| Error::serve(format!("cannot connect to {addr}: {e}")))?;
        stream.set_nodelay(true).ok();
        let reader = BufReader::new(stream.try_clone().map_err(Error::Io)?);
        Ok(Client { reader, writer: BufWriter::new(stream) })
    }

    /// Send one request and wait for the response.
    pub fn call(&mut self, req: &Request) -> Result<Response> {
        writeln!(self.writer, "{}", req.to_json().to_string()).map_err(Error::Io)?;
        self.writer.flush().map_err(Error::Io)?;
        let mut line = String::new();
        self.reader.read_line(&mut line).map_err(Error::Io)?;
        Response::from_json(&Json::parse(&line)?)
    }

    /// Ask the server to shut down.
    pub fn shutdown_server(&mut self) -> Result<()> {
        writeln!(self.writer, "{}", r#"{"op":"shutdown"}"#).map_err(Error::Io)?;
        self.writer.flush().map_err(Error::Io)?;
        let mut line = String::new();
        let _ = self.reader.read_line(&mut line);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Config, IndexKind};
    use crate::coordinator::Engine;
    use crate::data;
    use crate::util::rng::Pcg64;

    fn spawn_server() -> (String, std::thread::JoinHandle<()>, Arc<Engine>) {
        let mut cfg = Config::preset("tiny").unwrap();
        cfg.data.n = 1500;
        cfg.data.d = 8;
        cfg.index.kind = IndexKind::Ivf;
        cfg.index.n_clusters = 20;
        cfg.index.n_probe = 6;
        cfg.index.kmeans_iters = 3;
        cfg.index.train_sample = 800;
        let engine = Arc::new(Engine::from_config(&cfg, None).unwrap());
        let coord = Arc::new(Coordinator::start(engine.clone(), 2, 16, 9));
        let server = Server::bind(coord, "127.0.0.1:0").unwrap();
        let addr = server.local_addr().unwrap();
        let h = std::thread::spawn(move || {
            server.serve().unwrap();
        });
        (addr, h, engine)
    }

    #[test]
    fn client_server_roundtrip() {
        let (addr, handle, engine) = spawn_server();
        let mut client = Client::connect(&addr).unwrap();
        let mut rng = Pcg64::new(1);
        let theta = data::random_theta(&engine.ds, 0.05, &mut rng);

        match client.call(&Request::Sample { theta: theta.clone(), count: 3 }).unwrap() {
            Response::Samples { ids, .. } => assert_eq!(ids.len(), 3),
            other => panic!("{other:?}"),
        }
        match client.call(&Request::LogPartition { theta }).unwrap() {
            Response::LogPartition { log_z, .. } => assert!(log_z.is_finite()),
            other => panic!("{other:?}"),
        }
        // malformed line → error response, connection stays usable
        match client.call(&Request::Stats).unwrap() {
            Response::Stats { .. } => {}
            other => panic!("{other:?}"),
        }
        client.shutdown_server().unwrap();
        handle.join().unwrap();
    }

    #[test]
    fn shutdown_returns_despite_idle_connections() {
        // regression: an idle client used to pin its connection thread
        // inside a blocking read forever, so `serve()`'s final join never
        // returned after a shutdown issued on another connection
        let (addr, handle, _engine) = spawn_server();
        let idle = Client::connect(&addr).unwrap(); // never sends a byte
        let mut active = Client::connect(&addr).unwrap();
        match active.call(&Request::Stats).unwrap() {
            Response::Stats { .. } => {}
            other => panic!("{other:?}"),
        }
        active.shutdown_server().unwrap();
        // must return promptly even though `idle` is still open
        handle.join().unwrap();
        drop(idle);
    }

    #[test]
    fn bad_json_reported_not_fatal() {
        let (addr, handle, _engine) = spawn_server();
        let stream = TcpStream::connect(&addr).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut writer = BufWriter::new(stream);
        writeln!(writer, "this is not json").unwrap();
        writer.flush().unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("\"ok\":false"));
        // still alive:
        writeln!(writer, "{}", r#"{"op":"stats"}"#).unwrap();
        writer.flush().unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("\"ok\":true"));
        writeln!(writer, "{}", r#"{"op":"shutdown"}"#).unwrap();
        writer.flush().unwrap();
        handle.join().unwrap();
    }
}
