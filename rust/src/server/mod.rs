//! TCP front-end: JSON-lines protocol over `std::net` (the offline
//! registry has no tokio; a thread-per-connection accept loop feeding the
//! coordinator's bounded queue gives the same backpressure semantics).
//!
//! Wire format: one JSON object per line, request → response
//! (see [`crate::coordinator::api`]). `{"op":"shutdown"}` stops the
//! server (used by tests and the CLI's `--oneshot` mode).
//!
//! The framing/accept layer is generic over a [`ServeHandler`], so the
//! same server fronts both the coordinator (inference API) and a
//! [`crate::remote::ShardEngine`] (shard-serving API). The front-end owns
//! the robustness knobs:
//!
//! * finished connection threads are reaped on every accept, and at most
//!   `serve.max_conns` connections run at once — excess connections get
//!   an immediate `overloaded` reply instead of a silent queue;
//! * request lines are capped at `serve.max_line_bytes`; longer lines
//!   are answered with an error and the connection resynchronizes at the
//!   next newline instead of buffering without bound;
//! * under queue saturation the coordinator handler stops blocking in
//!   `submit` and sheds with an explicit `overloaded` error after
//!   `serve.shed_ms` (bounded worst-case latency);
//! * an optional [`FaultPlan`] injects failures (drops, delays, corrupt
//!   frames, a kill switch) at well-defined points for the fault drills.

use crate::config::ServeConfig;
use crate::coordinator::{api::Request, Coordinator, Response};
use crate::error::{Error, Result};
use crate::remote::faults::FaultPlan;
use crate::util::json::Json;
use crate::util::timing::Stopwatch;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// Answers parsed request objects for a [`Server`]. Implementations must
/// be cheap to share across connection threads.
pub trait ServeHandler: Send + Sync {
    /// Answer one parsed request object (already valid JSON).
    fn respond(&self, req: &Json) -> Json;

    /// Shape an error (bad json, oversized line, overload) as a reply in
    /// this handler's wire format. The default matches both the
    /// coordinator and shard protocols.
    fn error(&self, message: &str) -> Json {
        Json::obj(vec![("ok", Json::Bool(false)), ("error", Json::str(message))])
    }
}

/// [`ServeHandler`] fronting the [`Coordinator`]: parses the typed
/// [`Request`], enqueues with a bounded shed deadline instead of blocking
/// forever on a saturated queue, and annotates `stats` responses with
/// queue depth and shed count.
pub struct CoordHandler {
    coordinator: Arc<Coordinator>,
    shed_ms: u64,
}

impl CoordHandler {
    pub fn new(coordinator: Arc<Coordinator>, shed_ms: u64) -> Self {
        CoordHandler { coordinator, shed_ms }
    }
}

impl ServeHandler for CoordHandler {
    fn respond(&self, j: &Json) -> Json {
        let req = match Request::from_json(j) {
            Ok(r) => r,
            Err(e) => return self.error(&e.to_string()),
        };
        // Deadline-aware enqueue: a full queue is retried for at most
        // `shed_ms`, then the request is shed with an explicit error —
        // saturation degrades into bounded-latency rejections, never
        // into an unbounded blocking pile-up of connection threads.
        let sw = Stopwatch::start();
        let ticket = loop {
            match self.coordinator.try_submit(req.clone()) {
                Ok(t) => break Some(t),
                Err(_) if sw.millis() < self.shed_ms as f64 => {
                    std::thread::sleep(std::time::Duration::from_millis(1));
                }
                Err(_) => break None,
            }
        };
        let resp = match ticket {
            None => {
                self.coordinator.note_shed();
                Response::Error { message: "overloaded: coordinator queue full".into() }
            }
            Some(t) => match t.wait() {
                Ok(r) => r,
                Err(e) => Response::Error { message: e.to_string() },
            },
        };
        crate::obs::registry().queue_depth.set(self.coordinator.queue_depth() as i64);
        let resp = match resp {
            Response::Stats { text, mut numbers } => {
                numbers.queue_depth = self.coordinator.queue_depth() as u64;
                numbers.shed = self.coordinator.shed_count();
                Response::Stats {
                    text: format!(
                        "{text}\nserve: queue_depth={} shed={}",
                        self.coordinator.queue_depth(),
                        self.coordinator.shed_count()
                    ),
                    numbers,
                }
            }
            r => r,
        };
        resp.to_json()
    }
}

/// Blocking JSON-lines server.
pub struct Server {
    handler: Arc<dyn ServeHandler>,
    listener: TcpListener,
    stop: Arc<AtomicBool>,
    max_conns: usize,
    max_line_bytes: usize,
    faults: Option<Arc<FaultPlan>>,
}

impl Server {
    /// Bind a coordinator front-end to `addr` (e.g. `127.0.0.1:7431`;
    /// port 0 picks a free port) with the default serve limits.
    pub fn bind(coordinator: Arc<Coordinator>, addr: &str) -> Result<Server> {
        let serve = crate::config::Config::default().serve;
        Self::bind_with(coordinator, addr, &serve)
    }

    /// [`bind`](Self::bind) with explicit serve limits.
    pub fn bind_with(
        coordinator: Arc<Coordinator>,
        addr: &str,
        serve: &ServeConfig,
    ) -> Result<Server> {
        let handler = Arc::new(CoordHandler::new(coordinator, serve.shed_ms));
        Self::bind_handler(handler, addr, serve)
    }

    /// Bind an arbitrary handler (e.g. a shard engine) to `addr`.
    pub fn bind_handler(
        handler: Arc<dyn ServeHandler>,
        addr: &str,
        serve: &ServeConfig,
    ) -> Result<Server> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| Error::serve(format!("cannot bind {addr}: {e}")))?;
        Ok(Server {
            handler,
            listener,
            stop: Arc::new(AtomicBool::new(false)),
            max_conns: serve.max_conns.max(1),
            max_line_bytes: serve.max_line_bytes.max(256),
            faults: None,
        })
    }

    /// Attach a fault-injection plan (tests / drills). The plan is
    /// consulted live, so flipping its knobs affects a running server.
    pub fn with_faults(mut self, plan: Arc<FaultPlan>) -> Server {
        self.faults = Some(plan);
        self
    }

    /// The actually-bound address.
    pub fn local_addr(&self) -> Result<String> {
        Ok(self.listener.local_addr().map_err(Error::Io)?.to_string())
    }

    /// Serve until a `shutdown` op arrives. Each connection gets its own
    /// thread; requests within a connection are processed in order.
    pub fn serve(&self) -> Result<()> {
        // polling accept so the stop flag is honoured promptly
        self.listener.set_nonblocking(true).map_err(Error::Io)?;
        let mut conns: Vec<std::thread::JoinHandle<()>> = Vec::new();
        while !self.stop.load(Ordering::SeqCst) {
            match self.listener.accept() {
                Ok((stream, _)) => {
                    // reap finished connection threads so a long-lived
                    // server doesn't leak one JoinHandle per past client
                    conns.retain(|h| !h.is_finished());
                    if let Some(f) = &self.faults {
                        if f.is_down() {
                            drop(stream); // killed shard: refuse service
                            continue;
                        }
                    }
                    if conns.len() >= self.max_conns {
                        // over the connection cap: explicit overloaded
                        // reply and close, never a silent queue
                        let reply = self.handler.error("overloaded: too many connections");
                        let mut w = BufWriter::new(stream);
                        let _ = writeln!(w, "{}", reply.to_string());
                        let _ = w.flush();
                        continue;
                    }
                    let handler = self.handler.clone();
                    let stop = self.stop.clone();
                    let faults = self.faults.clone();
                    let cap = self.max_line_bytes;
                    conns.push(std::thread::spawn(move || {
                        let _ = handle_conn(stream, &*handler, &stop, cap, faults.as_deref());
                    }));
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(std::time::Duration::from_millis(5));
                }
                Err(e) => return Err(Error::Io(e)),
            }
        }
        for c in conns {
            let _ = c.join();
        }
        Ok(())
    }

    /// Handle for stopping from another thread.
    pub fn stop_flag(&self) -> Arc<AtomicBool> {
        self.stop.clone()
    }
}

fn write_json(writer: &mut BufWriter<TcpStream>, j: &Json) -> Result<()> {
    writeln!(writer, "{}", j.to_string()).map_err(Error::Io)?;
    writer.flush().map_err(Error::Io)
}

fn handle_conn(
    stream: TcpStream,
    handler: &dyn ServeHandler,
    stop: &AtomicBool,
    max_line: usize,
    faults: Option<&FaultPlan>,
) -> Result<()> {
    stream.set_nodelay(true).ok();
    // A blocking `reader.lines()` loop would pin this thread inside
    // `read` for as long as the client keeps the connection open but
    // idle — `serve()`'s final `join` would then never return after a
    // shutdown requested on *another* connection. Poll with a short read
    // timeout instead so the stop flag is honoured promptly.
    stream
        .set_read_timeout(Some(std::time::Duration::from_millis(25)))
        .map_err(Error::Io)?;
    let mut reader = BufReader::new(stream.try_clone().map_err(Error::Io)?);
    let mut writer = BufWriter::new(stream);
    // Raw byte buffer, NOT read_line: `read_until` appends whatever was
    // read even when it errors, so a request split across the timeout
    // boundary is completed by the next iteration — read_line would
    // discard already-consumed bytes whenever the partial read ends
    // mid-way through a multibyte UTF-8 character, desynchronizing the
    // framing.
    let mut buf: Vec<u8> = Vec::new();
    // true while discarding the tail of an oversized line (the error was
    // already sent; framing resynchronizes at the next newline)
    let mut dropping = false;
    loop {
        if stop.load(Ordering::SeqCst) {
            return Ok(());
        }
        if let Some(f) = faults {
            if f.is_down() {
                return Ok(()); // killed shard: sever mid-stream
            }
        }
        match reader.read_until(b'\n', &mut buf) {
            Ok(0) => return Ok(()), // EOF: client went away
            Ok(_) => {}
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                // partial read: bound memory for a line that never ends
                if buf.len() > max_line && !dropping {
                    write_json(
                        &mut writer,
                        &handler.error(&format!("request line exceeds {max_line} bytes")),
                    )?;
                    dropping = true;
                }
                if dropping {
                    buf.clear();
                }
                continue;
            }
            Err(e) => return Err(Error::Io(e)),
        }
        let ended = buf.last() == Some(&b'\n');
        if dropping {
            // still inside the oversized line: discard through its newline
            buf.clear();
            if ended {
                dropping = false;
            }
            continue;
        }
        if buf.len() > max_line {
            write_json(
                &mut writer,
                &handler.error(&format!("request line exceeds {max_line} bytes")),
            )?;
            buf.clear();
            continue;
        }
        let line = String::from_utf8_lossy(&buf);
        if line.trim().is_empty() {
            buf.clear();
            continue;
        }
        if let Some(f) = faults {
            if f.armed() {
                let ms = f.delay_ms();
                if ms > 0 {
                    std::thread::sleep(std::time::Duration::from_millis(ms));
                }
                if f.is_down() || f.take_drop() {
                    return Ok(()); // sever instead of answering
                }
                if f.take_corrupt() {
                    writeln!(writer, "{{\"ok\":tr%garbage").map_err(Error::Io)?;
                    writer.flush().map_err(Error::Io)?;
                    buf.clear();
                    continue;
                }
            }
        }
        let reply = match Json::parse(&line) {
            Err(e) => handler.error(&format!("bad json: {e}")),
            Ok(j) => {
                if j.get("op").and_then(|o| o.as_str().ok()) == Some("shutdown") {
                    stop.store(true, Ordering::SeqCst);
                    let ack = Json::obj(vec![
                        ("ok", Json::Bool(true)),
                        ("stats", Json::str("shutting down")),
                    ]);
                    write_json(&mut writer, &ack)?;
                    return Ok(());
                }
                handler.respond(&j)
            }
        };
        write_json(&mut writer, &reply)?;
        buf.clear();
    }
}

/// Blocking JSON-lines client.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr)
            .map_err(|e| Error::serve(format!("cannot connect to {addr}: {e}")))?;
        Self::from_stream(stream)
    }

    /// [`connect`](Self::connect) with a bounded TCP connect timeout
    /// (tries each resolved address in turn).
    pub fn connect_timeout(addr: &str, timeout: std::time::Duration) -> Result<Client> {
        use std::net::ToSocketAddrs;
        let addrs = addr
            .to_socket_addrs()
            .map_err(|e| Error::serve(format!("cannot resolve {addr}: {e}")))?;
        let mut last: Option<std::io::Error> = None;
        for a in addrs {
            match TcpStream::connect_timeout(&a, timeout) {
                Ok(s) => return Self::from_stream(s),
                Err(e) => last = Some(e),
            }
        }
        let why = last.map(|e| e.to_string()).unwrap_or_else(|| "no addresses resolved".into());
        Err(Error::serve(format!("cannot connect to {addr}: {why}")))
    }

    fn from_stream(stream: TcpStream) -> Result<Client> {
        stream.set_nodelay(true).ok();
        let reader = BufReader::new(stream.try_clone().map_err(Error::Io)?);
        Ok(Client { reader, writer: BufWriter::new(stream) })
    }

    /// Read/write timeouts for subsequent calls (`None` = block forever).
    pub fn set_io_timeout(&mut self, timeout: Option<std::time::Duration>) -> Result<()> {
        let s = self.reader.get_ref();
        s.set_read_timeout(timeout).map_err(Error::Io)?;
        s.set_write_timeout(timeout).map_err(Error::Io)
    }

    /// Send one request and wait for the response.
    pub fn call(&mut self, req: &Request) -> Result<Response> {
        let line = self.call_line(&req.to_json().to_string())?;
        Response::from_json(&Json::parse(&line)?)
    }

    /// Send one raw JSON line and read one reply line (shared by the
    /// typed coordinator calls and the remote shard protocol).
    pub fn call_line(&mut self, request_line: &str) -> Result<String> {
        writeln!(self.writer, "{request_line}").map_err(Error::Io)?;
        self.writer.flush().map_err(Error::Io)?;
        let mut line = String::new();
        let n = self.reader.read_line(&mut line).map_err(Error::Io)?;
        if n == 0 {
            // read_line's Ok(0) is a silent EOF — surface it as an
            // explicit failure so callers retry/reconnect instead of
            // parsing an empty string
            return Err(Error::serve("server closed connection"));
        }
        Ok(line)
    }

    /// Ask the server to shut down.
    pub fn shutdown_server(&mut self) -> Result<()> {
        writeln!(self.writer, "{}", r#"{"op":"shutdown"}"#).map_err(Error::Io)?;
        self.writer.flush().map_err(Error::Io)?;
        let mut line = String::new();
        let _ = self.reader.read_line(&mut line);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Config, IndexKind};
    use crate::coordinator::Engine;
    use crate::data;
    use crate::util::rng::Pcg64;

    fn tiny_cfg() -> Config {
        let mut cfg = Config::preset("tiny").unwrap();
        cfg.data.n = 1500;
        cfg.data.d = 8;
        cfg.index.kind = IndexKind::Ivf;
        cfg.index.n_clusters = 20;
        cfg.index.n_probe = 6;
        cfg.index.kmeans_iters = 3;
        cfg.index.train_sample = 800;
        cfg
    }

    fn spawn_server_with(
        serve: Option<ServeConfig>,
    ) -> (String, std::thread::JoinHandle<()>, Arc<Engine>) {
        let cfg = tiny_cfg();
        let engine = Arc::new(Engine::from_config(&cfg, None).unwrap());
        let coord = Arc::new(Coordinator::start(engine.clone(), 2, 16, 9));
        let server = match serve {
            Some(s) => Server::bind_with(coord, "127.0.0.1:0", &s).unwrap(),
            None => Server::bind(coord, "127.0.0.1:0").unwrap(),
        };
        let addr = server.local_addr().unwrap();
        let h = std::thread::spawn(move || {
            server.serve().unwrap();
        });
        (addr, h, engine)
    }

    fn spawn_server() -> (String, std::thread::JoinHandle<()>, Arc<Engine>) {
        spawn_server_with(None)
    }

    #[test]
    fn client_server_roundtrip() {
        let (addr, handle, engine) = spawn_server();
        let mut client = Client::connect(&addr).unwrap();
        let mut rng = Pcg64::new(1);
        let theta = data::random_theta(&engine.ds, 0.05, &mut rng);

        match client.call(&Request::Sample { theta: theta.clone(), count: 3 }).unwrap() {
            Response::Samples { ids, .. } => assert_eq!(ids.len(), 3),
            other => panic!("{other:?}"),
        }
        match client.call(&Request::LogPartition { theta }).unwrap() {
            Response::LogPartition { log_z, .. } => assert!(log_z.is_finite()),
            other => panic!("{other:?}"),
        }
        // stats now carry the front-end's queue/shed counters
        match client.call(&Request::Stats).unwrap() {
            Response::Stats { text, numbers } => {
                assert!(text.contains("queue_depth="), "{text}");
                assert!(text.contains("shed="), "{text}");
                assert_eq!(numbers.shed, 0);
                assert!(!numbers.snapshot_degraded);
            }
            other => panic!("{other:?}"),
        }
        // the metrics op answers with a parseable Prometheus exposition
        match client.call(&Request::Metrics).unwrap() {
            Response::Metrics { exposition } => {
                assert!(exposition.contains("gmips_requests_total"), "{exposition}");
                crate::obs::parse_exposition(&exposition).unwrap();
            }
            other => panic!("{other:?}"),
        }
        client.shutdown_server().unwrap();
        handle.join().unwrap();
    }

    #[test]
    fn shutdown_returns_despite_idle_connections() {
        // regression: an idle client used to pin its connection thread
        // inside a blocking read forever, so `serve()`'s final join never
        // returned after a shutdown issued on another connection
        let (addr, handle, _engine) = spawn_server();
        let idle = Client::connect(&addr).unwrap(); // never sends a byte
        let mut active = Client::connect(&addr).unwrap();
        match active.call(&Request::Stats).unwrap() {
            Response::Stats { .. } => {}
            other => panic!("{other:?}"),
        }
        active.shutdown_server().unwrap();
        // must return promptly even though `idle` is still open
        handle.join().unwrap();
        drop(idle);
    }

    #[test]
    fn bad_json_reported_not_fatal() {
        let (addr, handle, _engine) = spawn_server();
        let stream = TcpStream::connect(&addr).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut writer = BufWriter::new(stream);
        writeln!(writer, "this is not json").unwrap();
        writer.flush().unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("\"ok\":false"));
        // still alive:
        writeln!(writer, "{}", r#"{"op":"stats"}"#).unwrap();
        writer.flush().unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("\"ok\":true"));
        writeln!(writer, "{}", r#"{"op":"shutdown"}"#).unwrap();
        writer.flush().unwrap();
        handle.join().unwrap();
    }

    #[test]
    fn oversized_line_rejected_then_resynchronized() {
        let mut serve = Config::default().serve;
        serve.max_line_bytes = 1024;
        let (addr, handle, _engine) = spawn_server_with(Some(serve));
        let mut client = Client::connect(&addr).unwrap();
        // a 64 KiB garbage line must get an error, not unbounded buffering
        let big = "x".repeat(64 * 1024);
        let reply = client.call_line(&big).unwrap();
        assert!(reply.contains("exceeds"), "{reply}");
        assert!(reply.contains("\"ok\":false"), "{reply}");
        // framing resynchronizes at the newline: the next request works
        match client.call(&Request::Stats).unwrap() {
            Response::Stats { .. } => {}
            other => panic!("{other:?}"),
        }
        client.shutdown_server().unwrap();
        handle.join().unwrap();
    }

    #[test]
    fn connection_cap_sheds_with_overloaded() {
        let mut serve = Config::default().serve;
        serve.max_conns = 1;
        let (addr, handle, _engine) = spawn_server_with(Some(serve));
        let mut first = Client::connect(&addr).unwrap();
        // a completed call guarantees the first connection is registered
        match first.call(&Request::Stats).unwrap() {
            Response::Stats { .. } => {}
            other => panic!("{other:?}"),
        }
        // second connection is over the cap → explicit overloaded reply
        let mut second = Client::connect(&addr).unwrap();
        match second.call(&Request::Stats) {
            Ok(Response::Error { message }) => assert!(message.contains("overloaded"), "{message}"),
            other => panic!("expected overloaded error, got {other:?}"),
        }
        drop(second);
        first.shutdown_server().unwrap();
        handle.join().unwrap();
    }

    #[test]
    fn client_reports_server_close_as_clear_error() {
        // a server that hangs up mid-call must surface as an explicit
        // "closed connection" error, not an empty-string parse failure
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let h = std::thread::spawn(move || {
            let (s, _) = listener.accept().unwrap();
            let mut r = BufReader::new(s);
            let mut line = String::new();
            r.read_line(&mut line).unwrap(); // swallow the request, hang up
        });
        let mut client = Client::connect(&addr).unwrap();
        let err = client.call(&Request::Stats).unwrap_err();
        h.join().unwrap();
        assert!(err.to_string().contains("server closed connection"), "{err}");
    }
}
