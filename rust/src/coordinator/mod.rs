//! The L3 serving layer: a worker-pool coordinator around the inference
//! [`Engine`], with bounded-queue backpressure and per-worker RNG streams.
//!
//! The amortization story of the paper is a *service* story: preprocessing
//! (dataset + MIPS index + AOT artifacts) happens once; then a stream of
//! queries with different θ — sampling, partition estimates, gradient
//! expectations — is answered in sublinear time each. The coordinator
//! makes that concrete: [`Coordinator::submit`] enqueues a request and
//! returns a handle; worker threads drain the queue against a shared
//! [`Engine`].
//!
//! Workers drain the queue in **batches** ([`WorkQueue::pop_batch`], up
//! to [`MAX_BATCH`] requests at a time): whatever has queued up while a
//! worker was busy comes off together and flows through
//! [`Engine::handle_batch`], which groups same-op requests into batched
//! MIPS retrievals — under concurrent multi-user traffic the index scans
//! amortize across the whole batch; when idle, batches have size one and
//! nothing changes.

pub mod api;
pub mod engine;

pub use api::{Request, Response, StatsNumbers};
pub use engine::{Engine, EngineMetrics};

use crate::error::{Error, Result};
use crate::util::pool::WorkQueue;
use crate::util::rng::Pcg64;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;

/// A pending response.
pub struct Ticket {
    rx: mpsc::Receiver<Response>,
}

impl Ticket {
    /// Block until the response arrives.
    pub fn wait(self) -> Result<Response> {
        self.rx
            .recv()
            .map_err(|_| Error::serve("coordinator dropped the request (shutting down?)"))
    }
}

struct Job {
    req: Request,
    tx: mpsc::Sender<Response>,
    /// when the request entered the queue (queue-wait attribution)
    enq: std::time::Instant,
    /// chosen for request tracing at submit time (1-in-N sampling)
    traced: bool,
}

impl Job {
    fn new(req: Request, tx: mpsc::Sender<Response>) -> Job {
        let traced = crate::obs::trace_try_sample();
        Job { req, tx, enq: std::time::Instant::now(), traced }
    }
}

/// Most requests a worker drains from the queue in one go. Bounds the
/// latency any single request can absorb from batch-mates while still
/// amortizing an index scan across a useful number of queries.
pub const MAX_BATCH: usize = 16;

/// Multi-threaded request coordinator.
pub struct Coordinator {
    engine: Arc<Engine>,
    queue: Arc<WorkQueue<Job>>,
    workers: Vec<JoinHandle<()>>,
    /// requests shed by the front-end because the queue stayed full past
    /// its shed deadline (`serve.shed_ms`)
    shed: AtomicU64,
}

impl Coordinator {
    /// Spawn `workers` threads (0 = all cores) over a queue of depth
    /// `queue_depth` (backpressure: `submit` blocks when full).
    pub fn start(engine: Arc<Engine>, workers: usize, queue_depth: usize, seed: u64) -> Coordinator {
        Self::start_with_wait(engine, workers, queue_depth, seed, 0)
    }

    /// [`start`](Self::start) with a bounded batching micro-wait: each
    /// worker lets a freshly drained batch deepen for up to
    /// `micro_wait_us` microseconds (via
    /// [`WorkQueue::pop_batch_wait`]) before serving it — deeper batches
    /// under moderate load, traded against a bounded p50 latency cost.
    /// `0` (the [`start`](Self::start) default and the
    /// `serve.micro_wait_us` config default) serves whatever is queued.
    pub fn start_with_wait(
        engine: Arc<Engine>,
        workers: usize,
        queue_depth: usize,
        seed: u64,
        micro_wait_us: u64,
    ) -> Coordinator {
        let workers = if workers == 0 { crate::util::pool::default_threads() } else { workers };
        let queue = Arc::new(WorkQueue::<Job>::new(queue_depth.max(1)));
        let wait = std::time::Duration::from_micros(micro_wait_us);
        let mut handles = Vec::with_capacity(workers);
        for w in 0..workers {
            let queue = queue.clone();
            let engine = engine.clone();
            let mut rng = Pcg64::new_stream(seed, w as u64 + 1);
            handles.push(std::thread::spawn(move || {
                while let Some(jobs) = queue.pop_batch_wait(MAX_BATCH, wait) {
                    let obs = crate::obs::registry();
                    if crate::obs::enabled() {
                        obs.batches.inc();
                        obs.batched_requests.add(jobs.len() as u64);
                        for job in &jobs {
                            obs.queue_wait_micros.record(job.enq.elapsed().as_secs_f64() * 1e6);
                        }
                    }
                    if jobs.len() == 1 {
                        let job = jobs.into_iter().next().unwrap();
                        let traced = job.traced;
                        let sw = crate::util::timing::Stopwatch::start();
                        if traced {
                            crate::obs::trace_begin();
                            crate::obs::trace_stage(
                                crate::obs::Stage::Queue,
                                job.enq.elapsed().as_secs_f64() * 1e6,
                            );
                        }
                        let resp = engine.handle(&job.req, &mut rng);
                        if traced {
                            crate::obs::trace_end(job.req.op_name(), sw.micros(), 1);
                        }
                        // receiver may have given up; that's fine
                        let _ = job.tx.send(resp);
                        continue;
                    }
                    // a batch carries at most one trace: the first sampled
                    // job stands in for the whole drained batch
                    let traced_at = jobs.iter().position(|j| j.traced);
                    let mut reqs = Vec::with_capacity(jobs.len());
                    let mut txs = Vec::with_capacity(jobs.len());
                    let mut waits = Vec::with_capacity(jobs.len());
                    for job in jobs {
                        waits.push(job.enq.elapsed().as_secs_f64() * 1e6);
                        reqs.push(job.req);
                        txs.push(job.tx);
                    }
                    let sw = crate::util::timing::Stopwatch::start();
                    if let Some(i) = traced_at {
                        crate::obs::trace_begin();
                        crate::obs::trace_stage(crate::obs::Stage::Queue, waits[i]);
                    }
                    let resps = engine.handle_batch(&reqs, &mut rng);
                    if let Some(i) = traced_at {
                        crate::obs::trace_end(reqs[i].op_name(), sw.micros(), reqs.len());
                    }
                    for (tx, resp) in txs.into_iter().zip(resps) {
                        let _ = tx.send(resp);
                    }
                }
            }));
        }
        Coordinator { engine, queue, workers: handles, shed: AtomicU64::new(0) }
    }

    /// Enqueue a request (blocks when the queue is full — backpressure).
    pub fn submit(&self, req: Request) -> Result<Ticket> {
        let (tx, rx) = mpsc::channel();
        if !self.queue.push(Job::new(req, tx)) {
            return Err(Error::serve("coordinator is shut down"));
        }
        Ok(Ticket { rx })
    }

    /// Try to enqueue without blocking; `Err` when saturated.
    pub fn try_submit(&self, req: Request) -> Result<Ticket> {
        let (tx, rx) = mpsc::channel();
        self.queue
            .try_push(Job::new(req, tx))
            .map_err(|_| Error::serve("queue full"))?;
        Ok(Ticket { rx })
    }

    /// Convenience: submit and wait.
    pub fn call(&self, req: Request) -> Result<Response> {
        self.submit(req)?.wait()
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    /// Requests currently waiting in the submission queue.
    pub fn queue_depth(&self) -> usize {
        self.queue.len()
    }

    /// Record one front-end load-shed (queue stayed full past the shed
    /// deadline and the request was answered `overloaded`).
    pub fn note_shed(&self) {
        self.shed.fetch_add(1, Ordering::Relaxed);
        crate::obs::registry().shed.inc();
    }

    /// Total requests shed so far.
    pub fn shed_count(&self) -> u64 {
        self.shed.load(Ordering::Relaxed)
    }

    /// Drain and stop all workers.
    pub fn shutdown(mut self) {
        self.queue.close();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.queue.close();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Config, IndexKind};
    use crate::data;

    fn tiny_engine() -> Arc<Engine> {
        let mut cfg = Config::preset("tiny").unwrap();
        cfg.data.n = 2000;
        cfg.data.d = 8;
        cfg.index.kind = IndexKind::Ivf;
        cfg.index.n_clusters = 30;
        cfg.index.n_probe = 8;
        cfg.index.kmeans_iters = 3;
        cfg.index.train_sample = 1000;
        Arc::new(Engine::from_config(&cfg, None).unwrap())
    }

    #[test]
    fn serves_concurrent_requests() {
        let engine = tiny_engine();
        let coord = Coordinator::start(engine.clone(), 3, 16, 42);
        let mut rng = Pcg64::new(1);
        let mut tickets = Vec::new();
        for _ in 0..20 {
            let theta = data::random_theta(&engine.ds, 0.05, &mut rng);
            tickets.push(coord.submit(Request::Sample { theta, count: 2 }).unwrap());
        }
        for t in tickets {
            match t.wait().unwrap() {
                Response::Samples { ids, .. } => assert_eq!(ids.len(), 2),
                other => panic!("{other:?}"),
            }
        }
        coord.shutdown();
    }

    #[test]
    fn mixed_workload_and_stats() {
        let engine = tiny_engine();
        let coord = Coordinator::start(engine.clone(), 2, 8, 7);
        let mut rng = Pcg64::new(2);
        let theta = data::random_theta(&engine.ds, 0.05, &mut rng);
        coord.call(Request::Sample { theta: theta.clone(), count: 1 }).unwrap();
        coord.call(Request::LogPartition { theta: theta.clone() }).unwrap();
        coord.call(Request::ExpectFeatures { theta }).unwrap();
        match coord.call(Request::Stats).unwrap() {
            Response::Stats { text, .. } => assert!(text.contains("n=2000")),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn micro_wait_still_serves_everything() {
        // the batching micro-wait must be a pure latency/depth trade —
        // every request still gets a well-formed response
        let engine = tiny_engine();
        let coord = Coordinator::start_with_wait(engine.clone(), 2, 32, 5, 200);
        let mut rng = Pcg64::new(6);
        let mut tickets = Vec::new();
        for _ in 0..12 {
            let theta = data::random_theta(&engine.ds, 0.05, &mut rng);
            tickets.push(coord.submit(Request::Sample { theta, count: 1 }).unwrap());
        }
        for t in tickets {
            match t.wait().unwrap() {
                Response::Samples { ids, .. } => assert_eq!(ids.len(), 1),
                other => panic!("{other:?}"),
            }
        }
        coord.shutdown();
    }

    #[test]
    fn submit_after_shutdown_errors() {
        let engine = tiny_engine();
        let coord = Coordinator::start(engine, 1, 4, 3);
        let q = coord.queue.clone();
        q.close();
        assert!(coord.submit(Request::Stats).is_err());
    }

    use crate::util::rng::Pcg64;
}
