//! The inference engine: one preprocessed database + MIPS index +
//! samplers/estimators, answering [`Request`]s.
//!
//! This is the single-threaded core; [`super::Coordinator`] wraps it in a
//! worker pool with per-worker RNG streams.

use super::api::{Request, Response};
use crate::config::Config;
use crate::data::{self, Dataset};
use crate::error::Result;
use crate::estimator::expectation::ExpectationEstimator;
use crate::estimator::partition::PartitionEstimator;
use crate::mips::{self, brute::BruteForce, MipsIndex};
use crate::sampler::lazy_gumbel::LazyGumbelSampler;
use crate::sampler::tv_bound;
use crate::sampler::Sampler;
use crate::scorer::{NativeScorer, ScoreBackend};
use crate::util::rng::Pcg64;
use crate::util::timing::{LatencyHistogram, Stopwatch};
use std::sync::Arc;

/// Per-operation service metrics.
#[derive(Default)]
pub struct EngineMetrics {
    pub sample: LatencyHistogram,
    pub topk: LatencyHistogram,
    pub partition: LatencyHistogram,
    pub expect: LatencyHistogram,
    pub tv: LatencyHistogram,
}

impl EngineMetrics {
    pub fn summary(&self) -> String {
        format!(
            "sample: {}\ntopk: {}\nlog_partition: {}\nexpect_features: {}\ntv_certify: {}",
            self.sample.summary(),
            self.topk.summary(),
            self.partition.summary(),
            self.expect.summary(),
            self.tv.summary()
        )
    }
}

/// Inference engine over a fixed database.
pub struct Engine {
    pub ds: Arc<Dataset>,
    pub index: Arc<dyn MipsIndex>,
    pub backend: Arc<dyn ScoreBackend>,
    pub sampler: LazyGumbelSampler,
    pub partition: PartitionEstimator,
    pub expectation: ExpectationEstimator,
    pub metrics: EngineMetrics,
    pub config: Config,
}

impl Engine {
    /// Build everything from config: generate/load data, build the index,
    /// wire the samplers/estimators with `k = k_mult·√n` etc.
    ///
    /// `backend` lets the caller inject a PJRT scorer; `None` = native.
    pub fn from_config(cfg: &Config, backend: Option<Arc<dyn ScoreBackend>>) -> Result<Engine> {
        let backend = backend.unwrap_or_else(|| Arc::new(NativeScorer));
        let ds = Arc::new(data::load_or_generate(&cfg.data));
        let index = mips::build_index(&ds, &cfg.index, backend.clone())?;
        Ok(Self::from_parts(cfg.clone(), ds, index, backend))
    }

    /// Assemble from prebuilt parts (tests, benches, examples).
    pub fn from_parts(
        config: Config,
        ds: Arc<Dataset>,
        index: Arc<dyn MipsIndex>,
        backend: Arc<dyn ScoreBackend>,
    ) -> Engine {
        // honour the index's measured gap if larger than the configured one
        let gap_c = config
            .sampler
            .gap_c
            .max(index.gap_bound().unwrap_or(0.0));
        let sampler = LazyGumbelSampler::new(
            ds.clone(),
            index.clone(),
            backend.clone(),
            config.sampler_k(),
            gap_c,
        );
        let partition = PartitionEstimator::new(
            ds.clone(),
            index.clone(),
            backend.clone(),
            config.estimator_k(),
            config.estimator_l(),
        );
        let expectation = ExpectationEstimator::new(
            ds.clone(),
            index.clone(),
            backend.clone(),
            config.estimator_k(),
            config.estimator_l(),
        );
        Engine {
            ds,
            index,
            backend,
            sampler,
            partition,
            expectation,
            metrics: EngineMetrics::default(),
            config,
        }
    }

    /// Handle one request (synchronously, on the caller's thread).
    pub fn handle(&self, req: &Request, rng: &mut Pcg64) -> Response {
        let sw = Stopwatch::start();
        let resp = match req {
            Request::Sample { theta, count } => {
                if theta.len() != self.ds.d {
                    return Self::dim_error(theta.len(), self.ds.d);
                }
                let outs = self.sampler.sample_many(theta, (*count).max(1), rng);
                let r = Response::Samples {
                    ids: outs.iter().map(|o| o.id).collect(),
                    scanned: outs.first().map(|o| o.work.scanned).unwrap_or(0),
                    tail_m: outs.iter().map(|o| o.work.m).sum(),
                };
                self.metrics.sample.record(sw.micros());
                r
            }
            Request::TopK { theta, k } => {
                if theta.len() != self.ds.d {
                    return Self::dim_error(theta.len(), self.ds.d);
                }
                let top = self.index.top_k(theta, (*k).max(1));
                let r = Response::TopK {
                    ids: top.items.iter().map(|s| s.id).collect(),
                    scores: top.items.iter().map(|s| s.score).collect(),
                };
                self.metrics.topk.record(sw.micros());
                r
            }
            Request::LogPartition { theta } => {
                if theta.len() != self.ds.d {
                    return Self::dim_error(theta.len(), self.ds.d);
                }
                let est = self.partition.estimate(theta, rng);
                let r = Response::LogPartition {
                    log_z: est.log_z,
                    k: est.work.k,
                    l: est.work.l,
                };
                self.metrics.partition.record(sw.micros());
                r
            }
            Request::ExpectFeatures { theta } => {
                if theta.len() != self.ds.d {
                    return Self::dim_error(theta.len(), self.ds.d);
                }
                let est = self.expectation.expect_features(theta, rng);
                let r = Response::Features { mean: est.mean, log_z: est.log_z };
                self.metrics.expect.record(sw.micros());
                r
            }
            Request::TvCertify { theta } => {
                if theta.len() != self.ds.d {
                    return Self::dim_error(theta.len(), self.ds.d);
                }
                let top = self.index.top_k(theta, self.sampler.k);
                let brute = BruteForce::new(self.ds.clone(), self.backend.clone());
                let mut all = vec![0f32; self.ds.n];
                brute.all_scores(theta, &mut all);
                let bound = tv_bound::tv_bound(&all, &top);
                self.metrics.tv.record(sw.micros());
                Response::Tv { bound }
            }
            Request::Stats => Response::Stats {
                text: format!(
                    "{}\nbackend={} k={} \n{}",
                    self.index.describe(),
                    self.backend.name(),
                    self.sampler.k,
                    self.metrics.summary()
                ),
            },
        };
        resp
    }

    fn dim_error(got: usize, want: usize) -> Response {
        Response::Error { message: format!("theta has dim {got}, database has dim {want}") }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::IndexKind;

    fn tiny_engine() -> Engine {
        let mut cfg = Config::preset("tiny").unwrap();
        cfg.data.n = 3000;
        cfg.data.d = 16;
        cfg.index.kind = IndexKind::Ivf;
        cfg.index.n_clusters = 40;
        cfg.index.n_probe = 10;
        cfg.index.kmeans_iters = 4;
        cfg.index.train_sample = 1500;
        Engine::from_config(&cfg, None).unwrap()
    }

    #[test]
    fn engine_serves_all_ops() {
        let e = tiny_engine();
        let mut rng = Pcg64::new(1);
        let theta = data::random_theta(&e.ds, e.config.data.temperature, &mut rng);

        match e.handle(&Request::Sample { theta: theta.clone(), count: 5 }, &mut rng) {
            Response::Samples { ids, scanned, .. } => {
                assert_eq!(ids.len(), 5);
                assert!(scanned > 0 && scanned < e.ds.n);
            }
            other => panic!("{other:?}"),
        }
        match e.handle(&Request::TopK { theta: theta.clone(), k: 7 }, &mut rng) {
            Response::TopK { ids, scores } => {
                assert_eq!(ids.len(), 7);
                assert_eq!(scores.len(), 7);
                assert!(scores.windows(2).all(|w| w[0] >= w[1]));
            }
            other => panic!("{other:?}"),
        }
        match e.handle(&Request::LogPartition { theta: theta.clone() }, &mut rng) {
            Response::LogPartition { log_z, k, l } => {
                assert!(log_z.is_finite());
                assert!(k > 0 && l > 0);
            }
            other => panic!("{other:?}"),
        }
        match e.handle(&Request::ExpectFeatures { theta: theta.clone() }, &mut rng) {
            Response::Features { mean, log_z } => {
                assert_eq!(mean.len(), e.ds.d);
                assert!(log_z.is_finite());
            }
            other => panic!("{other:?}"),
        }
        match e.handle(&Request::TvCertify { theta }, &mut rng) {
            Response::Tv { bound } => assert!((0.0..=1.0).contains(&bound)),
            other => panic!("{other:?}"),
        }
        match e.handle(&Request::Stats, &mut rng) {
            Response::Stats { text } => {
                assert!(text.contains("ivf"));
                assert!(text.contains("sample:"));
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn dimension_mismatch_is_graceful() {
        let e = tiny_engine();
        let mut rng = Pcg64::new(2);
        match e.handle(&Request::Sample { theta: vec![1.0; 3], count: 1 }, &mut rng) {
            Response::Error { message } => assert!(message.contains("dim")),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn metrics_accumulate() {
        let e = tiny_engine();
        let mut rng = Pcg64::new(3);
        let theta = data::random_theta(&e.ds, 0.05, &mut rng);
        for _ in 0..3 {
            e.handle(&Request::Sample { theta: theta.clone(), count: 1 }, &mut rng);
        }
        assert_eq!(e.metrics.sample.count(), 3);
    }
}
