//! The inference engine: one preprocessed database + MIPS index +
//! samplers/estimators, answering [`Request`]s.
//!
//! This is the single-threaded core; [`super::Coordinator`] wraps it in a
//! worker pool with per-worker RNG streams.

use super::api::{Request, Response};
use crate::config::Config;
use crate::data::{self, Dataset};
use crate::dispatch::{self, ExpectationDispatch, PartitionDispatch, SamplerDispatch};
use crate::error::{Error, Result};
use crate::mips::{brute::BruteForce, BuiltIndex, MipsIndex};
use crate::remote::{RemoteExpectation, RemoteIndex, RemotePartition, RemoteSampler, RemoteStack};
use crate::sampler::tv_bound;
use crate::scorer::{NativeScorer, ScoreBackend};
use crate::util::rng::Pcg64;
use crate::util::timing::{LatencyHistogram, Stopwatch};
use std::sync::Arc;

/// Per-operation service metrics.
#[derive(Default)]
pub struct EngineMetrics {
    pub sample: LatencyHistogram,
    pub topk: LatencyHistogram,
    pub partition: LatencyHistogram,
    pub expect: LatencyHistogram,
    pub tv: LatencyHistogram,
}

impl EngineMetrics {
    pub fn summary(&self) -> String {
        format!(
            "sample: {}\ntopk: {}\nlog_partition: {}\nexpect_features: {}\ntv_certify: {}",
            self.sample.summary(),
            self.topk.summary(),
            self.partition.summary(),
            self.expect.summary(),
            self.tv.summary()
        )
    }
}

/// Inference engine over a fixed database.
pub struct Engine {
    pub ds: Arc<Dataset>,
    pub index: Arc<dyn MipsIndex>,
    pub backend: Arc<dyn ScoreBackend>,
    pub sampler: SamplerDispatch,
    pub partition: PartitionDispatch,
    pub expectation: ExpectationDispatch,
    pub metrics: EngineMetrics,
    pub config: Config,
    /// `Some` when this engine fronts out-of-process shard servers
    /// ([`Engine::from_remote`]); the TopK path then fans out through the
    /// stack directly so it can surface per-shard health.
    pub remote: Option<Arc<RemoteStack>>,
    /// True when the index was warm-opened from a snapshot whose quantized
    /// shadow sections were corrupt: answers are bit-identical (served from
    /// the f32 tier) but the bandwidth savings are gone until a re-save.
    pub snapshot_degraded: bool,
}

impl Engine {
    /// Build everything from config: warm-open the snapshot at
    /// `index.path` when one exists (saving a fresh build there
    /// otherwise), or generate/load data and build the index, then wire
    /// the samplers/estimators with `k = k_mult·√n` etc.
    ///
    /// `backend` lets the caller inject a PJRT scorer; `None` = native.
    pub fn from_config(cfg: &Config, backend: Option<Arc<dyn ScoreBackend>>) -> Result<Engine> {
        let backend = backend.unwrap_or_else(|| Arc::new(NativeScorer));
        let opened = crate::store::load_or_build(cfg, backend.clone(), true)?;
        let mut engine = Self::from_parts(cfg.clone(), opened.ds, opened.index, backend);
        engine.snapshot_degraded = opened.degraded;
        Ok(engine)
    }

    /// Assemble from prebuilt parts (tests, benches, examples).
    ///
    /// `index` accepts anything convertible into a
    /// [`BuiltIndex`]: an `Arc<dyn MipsIndex>` gets the monolithic
    /// sampler/estimator stack, an `Arc<ShardedIndex>` (or the
    /// [`crate::mips::build_index_typed`] result) routes sampling, partition
    /// estimation and feature expectation through the sharded
    /// implementations — a server configured with `index.shards > 1` no
    /// longer silently falls back to the monolithic stack.
    pub fn from_parts(
        config: Config,
        ds: Arc<Dataset>,
        index: impl Into<BuiltIndex>,
        backend: Arc<dyn ScoreBackend>,
    ) -> Engine {
        let built = index.into();
        let (sampler, partition, expectation) =
            dispatch::build_stack(&config, &ds, &built, &backend);
        Engine {
            ds,
            index: built.as_dyn(),
            backend,
            sampler,
            partition,
            expectation,
            metrics: EngineMetrics::default(),
            config,
            remote: None,
            snapshot_degraded: false,
        }
    }

    /// Build a coordinator engine over **remote shard servers**
    /// (`remote.addrs`): every sample/partition/expectation/topk request
    /// fans out to the shard servers and merges their fragments, instead
    /// of scanning locally. The dataset is still materialized locally
    /// from the config seeds — it is the source of truth for dimension
    /// checks and the exact-scan `tv_certify` audit — and must agree
    /// with what the shard servers built from the same config.
    pub fn from_remote(cfg: &Config, backend: Option<Arc<dyn ScoreBackend>>) -> Result<Engine> {
        let backend = backend.unwrap_or_else(|| Arc::new(NativeScorer));
        let ds = Arc::new(data::load_or_generate(&cfg.data));
        let stack = Arc::new(RemoteStack::connect(cfg)?);
        if stack.n() != ds.n || stack.d() != ds.d {
            return Err(Error::config(format!(
                "shard servers hold n={} d={} but this config generates n={} d={} — \
                 coordinator and shard servers must share one config",
                stack.n(),
                stack.d(),
                ds.n,
                ds.d
            )));
        }
        let gap_c = cfg.sampler.gap_c.max(stack.gap().unwrap_or(0.0));
        let sampler = SamplerDispatch::Remote(RemoteSampler::new(
            stack.clone(),
            cfg.sampler_k(),
            gap_c,
            cfg.index.seed,
        ));
        let partition = PartitionDispatch::Remote(RemotePartition::new(stack.clone()));
        let expectation = ExpectationDispatch::Remote(RemoteExpectation::new(stack.clone()));
        let index: Arc<dyn MipsIndex> = Arc::new(RemoteIndex::new(stack.clone()));
        Ok(Engine {
            ds,
            index,
            backend,
            sampler,
            partition,
            expectation,
            metrics: EngineMetrics::default(),
            config: cfg.clone(),
            remote: Some(stack),
            snapshot_degraded: false,
        })
    }

    /// Mark a response degraded when the remote fan-out lost shards.
    fn wrap_status(r: Response, status: Option<(usize, usize)>) -> Response {
        match status {
            Some((ok, total)) if ok < total => {
                crate::obs::registry().remote_degraded_merges.inc();
                Response::Degraded { inner: Box::new(r), ok_shards: ok, shards: total }
            }
            _ => r,
        }
    }

    /// Handle one request (synchronously, on the caller's thread).
    pub fn handle(&self, req: &Request, rng: &mut Pcg64) -> Response {
        let sw = Stopwatch::start();
        let resp = match req {
            Request::Sample { theta, count } => {
                if theta.len() != self.ds.d {
                    return Self::dim_error(theta.len(), self.ds.d);
                }
                let many = self.sampler.sample_many_status(theta, (*count).max(1), rng);
                let (outs, status) = match many {
                    Ok(v) => v,
                    Err(e) => return Response::Error { message: e.to_string() },
                };
                let scanned = outs.first().map(|o| o.work.scanned).unwrap_or(0);
                let r = Self::wrap_status(
                    Response::Samples {
                        ids: outs.iter().map(|o| o.id).collect(),
                        scanned,
                        tail_m: outs.iter().map(|o| o.work.m).sum(),
                    },
                    status,
                );
                crate::obs::registry().request_rows_scanned.add(scanned as u64);
                self.metrics.sample.record(sw.micros());
                r
            }
            Request::TopK { theta, k } => {
                if theta.len() != self.ds.d {
                    return Self::dim_error(theta.len(), self.ds.d);
                }
                let (top, status) = if let Some(stack) = &self.remote {
                    match stack.top_k_status(&[theta.as_slice()], (*k).max(1)) {
                        Ok((mut v, st)) => (v.pop().unwrap_or_default(), Some(st)),
                        Err(e) => return Response::Error { message: e.to_string() },
                    }
                } else {
                    (self.index.top_k(theta, (*k).max(1)), None)
                };
                crate::obs::registry().request_rows_scanned.add(top.scanned as u64);
                let r = Self::wrap_status(
                    Response::TopK {
                        ids: top.items.iter().map(|s| s.id).collect(),
                        scores: top.items.iter().map(|s| s.score).collect(),
                    },
                    status,
                );
                self.metrics.topk.record(sw.micros());
                r
            }
            Request::LogPartition { theta } => {
                if theta.len() != self.ds.d {
                    return Self::dim_error(theta.len(), self.ds.d);
                }
                let (est, status) = match self.partition.estimate_status(theta, rng) {
                    Ok(v) => v,
                    Err(e) => return Response::Error { message: e.to_string() },
                };
                crate::obs::registry().request_rows_scanned.add(est.work.scanned as u64);
                let r = Self::wrap_status(
                    Response::LogPartition { log_z: est.log_z, k: est.work.k, l: est.work.l },
                    status,
                );
                self.metrics.partition.record(sw.micros());
                r
            }
            Request::ExpectFeatures { theta } => {
                if theta.len() != self.ds.d {
                    return Self::dim_error(theta.len(), self.ds.d);
                }
                let (est, status) = match self.expectation.expect_features_status(theta, rng) {
                    Ok(v) => v,
                    Err(e) => return Response::Error { message: e.to_string() },
                };
                crate::obs::registry().request_rows_scanned.add(est.work.scanned as u64);
                let r = Self::wrap_status(
                    Response::Features { mean: est.mean, log_z: est.log_z },
                    status,
                );
                self.metrics.expect.record(sw.micros());
                r
            }
            Request::TvCertify { theta } => {
                if theta.len() != self.ds.d {
                    return Self::dim_error(theta.len(), self.ds.d);
                }
                let top = self.index.top_k(theta, self.sampler.k());
                let brute = BruteForce::new(self.ds.clone(), self.backend.clone());
                let mut all = vec![0f32; self.ds.n];
                brute.all_scores(theta, &mut all);
                let bound = tv_bound::tv_bound(&all, &top);
                self.metrics.tv.record(sw.micros());
                Response::Tv { bound }
            }
            Request::Stats => {
                let obs = crate::obs::registry();
                Response::Stats {
                    text: format!(
                        "{}\nbackend={} simd={} k={} sampler={} partition={} expectation={} \
                         snapshot_degraded={}\n{}",
                        self.index.describe(),
                        self.backend.name(),
                        crate::linalg::simd::kernel().name(),
                        self.sampler.k(),
                        self.sampler.name(),
                        self.partition.name(),
                        self.expectation.name(),
                        self.snapshot_degraded,
                        self.metrics.summary()
                    ),
                    // queue_depth/shed are coordinator state: the server
                    // front-end fills them in before answering
                    numbers: super::api::StatsNumbers {
                        certificate_hit_rate: obs.cert_hit_rate(),
                        scanned_rows_per_request: obs.rows_per_request(),
                        queue_depth: 0,
                        shed: 0,
                        snapshot_degraded: self.snapshot_degraded,
                    },
                }
            }
            Request::Metrics => self.handle_metrics(),
        };
        // the metrics op itself stays out of the request counters so a
        // scrape doesn't perturb what it reports
        if !matches!(req, Request::Metrics) {
            crate::obs::registry().requests.inc();
        }
        resp
    }

    /// Render the obs registry (plus this engine's per-op latency
    /// histograms); when fronting remote shards, fan the `metrics` op out
    /// and merge the shard expositions under `shard="<id>"` labels.
    fn handle_metrics(&self) -> Response {
        let m = &self.metrics;
        let extra = crate::obs::ExtraMetrics {
            op_hists: vec![
                ("sample", &m.sample),
                ("topk", &m.topk),
                ("partition", &m.partition),
                ("expect", &m.expect),
                ("tv", &m.tv),
            ],
            ..Default::default()
        };
        let local = crate::obs::render_with(&extra);
        match &self.remote {
            None => Response::Metrics { exposition: local },
            Some(stack) => match stack.metrics_status() {
                Ok((shards, status)) => Self::wrap_status(
                    Response::Metrics { exposition: crate::obs::aggregate(&local, &shards) },
                    Some(status),
                ),
                Err(e) => Response::Error { message: e.to_string() },
            },
        }
    }

    /// Handle a drained batch of requests, grouping batchable operations
    /// so index scans amortize across concurrent users: `sample`,
    /// `log_partition` and `expect_features` requests share one
    /// [`MipsIndex::top_k_batch`] retrieval per group, and `topk`
    /// requests batch per distinct `k`. Everything else (TV audits,
    /// stats, dimension errors) falls through to [`handle`](Self::handle).
    /// Responses come back in request order.
    pub fn handle_batch(&self, reqs: &[Request], rng: &mut Pcg64) -> Vec<Response> {
        if reqs.len() == 1 {
            return vec![self.handle(&reqs[0], rng)];
        }
        let d = self.ds.d;
        let mut resps: Vec<Option<Response>> = vec![None; reqs.len()];
        let mut samples: Vec<usize> = Vec::new();
        let mut partitions: Vec<usize> = Vec::new();
        let mut expects: Vec<usize> = Vec::new();
        let mut topks: rustc_hash::FxHashMap<usize, Vec<usize>> = Default::default();
        for (i, req) in reqs.iter().enumerate() {
            match req {
                Request::Sample { theta, .. } if theta.len() == d => samples.push(i),
                Request::LogPartition { theta } if theta.len() == d => partitions.push(i),
                Request::ExpectFeatures { theta } if theta.len() == d => expects.push(i),
                Request::TopK { theta, k } if theta.len() == d => {
                    topks.entry((*k).max(1)).or_default().push(i)
                }
                _ => resps[i] = Some(self.handle(req, rng)),
            }
        }

        if !samples.is_empty() {
            let sw = Stopwatch::start();
            let mut qs: Vec<&[f32]> = Vec::with_capacity(samples.len());
            let mut counts: Vec<usize> = Vec::with_capacity(samples.len());
            for &i in &samples {
                if let Request::Sample { theta, count } = &reqs[i] {
                    qs.push(theta.as_slice());
                    counts.push((*count).max(1));
                }
            }
            match self.sampler.sample_batch_status(&qs, &counts, rng) {
                Ok((all, status)) => {
                    let micros = sw.micros() / samples.len() as f64;
                    for (&i, outs) in samples.iter().zip(all) {
                        let scanned = outs.first().map(|o| o.work.scanned).unwrap_or(0);
                        crate::obs::registry().request_rows_scanned.add(scanned as u64);
                        resps[i] = Some(Self::wrap_status(
                            Response::Samples {
                                ids: outs.iter().map(|o| o.id).collect(),
                                scanned,
                                tail_m: outs.iter().map(|o| o.work.m).sum(),
                            },
                            status,
                        ));
                        self.metrics.sample.record(micros);
                    }
                }
                Err(e) => {
                    for &i in &samples {
                        resps[i] = Some(Response::Error { message: e.to_string() });
                    }
                }
            }
            crate::obs::registry().requests.add(samples.len() as u64);
        }

        if !partitions.is_empty() {
            let sw = Stopwatch::start();
            let mut qs: Vec<&[f32]> = Vec::with_capacity(partitions.len());
            for &i in &partitions {
                if let Request::LogPartition { theta } = &reqs[i] {
                    qs.push(theta.as_slice());
                }
            }
            match self.partition.estimate_batch_status(&qs, rng) {
                Ok((ests, status)) => {
                    let micros = sw.micros() / partitions.len() as f64;
                    for (&i, est) in partitions.iter().zip(ests) {
                        crate::obs::registry().request_rows_scanned.add(est.work.scanned as u64);
                        resps[i] = Some(Self::wrap_status(
                            Response::LogPartition {
                                log_z: est.log_z,
                                k: est.work.k,
                                l: est.work.l,
                            },
                            status,
                        ));
                        self.metrics.partition.record(micros);
                    }
                }
                Err(e) => {
                    for &i in &partitions {
                        resps[i] = Some(Response::Error { message: e.to_string() });
                    }
                }
            }
            crate::obs::registry().requests.add(partitions.len() as u64);
        }

        if !expects.is_empty() {
            let sw = Stopwatch::start();
            let mut qs: Vec<&[f32]> = Vec::with_capacity(expects.len());
            for &i in &expects {
                if let Request::ExpectFeatures { theta } = &reqs[i] {
                    qs.push(theta.as_slice());
                }
            }
            match self.expectation.expect_features_batch_status(&qs, rng) {
                Ok((ests, status)) => {
                    let micros = sw.micros() / expects.len() as f64;
                    for (&i, est) in expects.iter().zip(ests) {
                        crate::obs::registry().request_rows_scanned.add(est.work.scanned as u64);
                        resps[i] = Some(Self::wrap_status(
                            Response::Features { mean: est.mean, log_z: est.log_z },
                            status,
                        ));
                        self.metrics.expect.record(micros);
                    }
                }
                Err(e) => {
                    for &i in &expects {
                        resps[i] = Some(Response::Error { message: e.to_string() });
                    }
                }
            }
            crate::obs::registry().requests.add(expects.len() as u64);
        }

        for (k, idxs) in topks {
            let sw = Stopwatch::start();
            let mut qs: Vec<&[f32]> = Vec::with_capacity(idxs.len());
            for &i in &idxs {
                if let Request::TopK { theta, .. } = &reqs[i] {
                    qs.push(theta.as_slice());
                }
            }
            let (tops, status) = if let Some(stack) = &self.remote {
                match stack.top_k_status(&qs, k) {
                    Ok((v, st)) => (v, Some(st)),
                    Err(e) => {
                        for &i in &idxs {
                            resps[i] = Some(Response::Error { message: e.to_string() });
                        }
                        continue;
                    }
                }
            } else {
                (self.index.top_k_batch(&qs, k), None)
            };
            let micros = sw.micros() / idxs.len() as f64;
            crate::obs::registry().requests.add(idxs.len() as u64);
            for (&i, top) in idxs.iter().zip(tops) {
                crate::obs::registry().request_rows_scanned.add(top.scanned as u64);
                resps[i] = Some(Self::wrap_status(
                    Response::TopK {
                        ids: top.items.iter().map(|s| s.id).collect(),
                        scores: top.items.iter().map(|s| s.score).collect(),
                    },
                    status,
                ));
                self.metrics.topk.record(micros);
            }
        }

        resps
            .into_iter()
            .map(|r| r.expect("every batched request must be answered"))
            .collect()
    }

    fn dim_error(got: usize, want: usize) -> Response {
        Response::Error { message: format!("theta has dim {got}, database has dim {want}") }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::IndexKind;

    fn tiny_engine() -> Engine {
        let mut cfg = Config::preset("tiny").unwrap();
        cfg.data.n = 3000;
        cfg.data.d = 16;
        cfg.index.kind = IndexKind::Ivf;
        cfg.index.n_clusters = 40;
        cfg.index.n_probe = 10;
        cfg.index.kmeans_iters = 4;
        cfg.index.train_sample = 1500;
        Engine::from_config(&cfg, None).unwrap()
    }

    #[test]
    fn engine_serves_all_ops() {
        let e = tiny_engine();
        let mut rng = Pcg64::new(1);
        let theta = data::random_theta(&e.ds, e.config.data.temperature, &mut rng);

        match e.handle(&Request::Sample { theta: theta.clone(), count: 5 }, &mut rng) {
            Response::Samples { ids, scanned, .. } => {
                assert_eq!(ids.len(), 5);
                assert!(scanned > 0 && scanned < e.ds.n);
            }
            other => panic!("{other:?}"),
        }
        match e.handle(&Request::TopK { theta: theta.clone(), k: 7 }, &mut rng) {
            Response::TopK { ids, scores } => {
                assert_eq!(ids.len(), 7);
                assert_eq!(scores.len(), 7);
                assert!(scores.windows(2).all(|w| w[0] >= w[1]));
            }
            other => panic!("{other:?}"),
        }
        match e.handle(&Request::LogPartition { theta: theta.clone() }, &mut rng) {
            Response::LogPartition { log_z, k, l } => {
                assert!(log_z.is_finite());
                assert!(k > 0 && l > 0);
            }
            other => panic!("{other:?}"),
        }
        match e.handle(&Request::ExpectFeatures { theta: theta.clone() }, &mut rng) {
            Response::Features { mean, log_z } => {
                assert_eq!(mean.len(), e.ds.d);
                assert!(log_z.is_finite());
            }
            other => panic!("{other:?}"),
        }
        match e.handle(&Request::TvCertify { theta }, &mut rng) {
            Response::Tv { bound } => assert!((0.0..=1.0).contains(&bound)),
            other => panic!("{other:?}"),
        }
        match e.handle(&Request::Stats, &mut rng) {
            Response::Stats { text, numbers } => {
                assert!(text.contains("ivf"));
                assert!(text.contains("sample:"));
                assert!(!numbers.snapshot_degraded);
            }
            other => panic!("{other:?}"),
        }
        match e.handle(&Request::Metrics, &mut rng) {
            Response::Metrics { exposition } => {
                assert!(exposition.contains("gmips_requests_total"), "{exposition}");
                assert!(exposition.contains(r#"gmips_engine_op_micros_count{op="sample"}"#));
                crate::obs::parse_exposition(&exposition).unwrap();
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn dimension_mismatch_is_graceful() {
        let e = tiny_engine();
        let mut rng = Pcg64::new(2);
        match e.handle(&Request::Sample { theta: vec![1.0; 3], count: 1 }, &mut rng) {
            Response::Error { message } => assert!(message.contains("dim")),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn handle_batch_matches_single_shapes_and_orders() {
        let e = tiny_engine();
        let mut rng = Pcg64::new(4);
        let theta = data::random_theta(&e.ds, 0.05, &mut rng);
        let reqs = vec![
            Request::Sample { theta: theta.clone(), count: 3 },
            Request::TopK { theta: theta.clone(), k: 5 },
            Request::LogPartition { theta: theta.clone() },
            Request::Sample { theta: theta.clone(), count: 2 },
            Request::ExpectFeatures { theta: theta.clone() },
            Request::TopK { theta: theta.clone(), k: 5 },
            Request::Sample { theta: vec![1.0; 3], count: 1 }, // dim error
            Request::Stats,
        ];
        let resps = e.handle_batch(&reqs, &mut rng);
        assert_eq!(resps.len(), reqs.len());
        match &resps[0] {
            Response::Samples { ids, .. } => assert_eq!(ids.len(), 3),
            other => panic!("{other:?}"),
        }
        match (&resps[1], &resps[5]) {
            (Response::TopK { ids: a, scores: sa }, Response::TopK { ids: b, scores: sb }) => {
                assert_eq!(a.len(), 5);
                // identical θ, identical k → identical deterministic result
                assert_eq!(a, b);
                assert_eq!(sa, sb);
                // and identical to the single-request path
                match e.handle(&Request::TopK { theta: theta.clone(), k: 5 }, &mut rng) {
                    Response::TopK { ids, scores } => {
                        assert_eq!(&ids, a);
                        assert_eq!(&scores, sa);
                    }
                    other => panic!("{other:?}"),
                }
            }
            other => panic!("{other:?}"),
        }
        match &resps[2] {
            Response::LogPartition { log_z, .. } => assert!(log_z.is_finite()),
            other => panic!("{other:?}"),
        }
        match &resps[3] {
            Response::Samples { ids, .. } => assert_eq!(ids.len(), 2),
            other => panic!("{other:?}"),
        }
        match &resps[4] {
            Response::Features { mean, .. } => assert_eq!(mean.len(), e.ds.d),
            other => panic!("{other:?}"),
        }
        match &resps[6] {
            Response::Error { message } => assert!(message.contains("dim")),
            other => panic!("{other:?}"),
        }
        match &resps[7] {
            Response::Stats { text, .. } => assert!(text.contains("simd=")),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn metrics_accumulate() {
        let e = tiny_engine();
        let mut rng = Pcg64::new(3);
        let theta = data::random_theta(&e.ds, 0.05, &mut rng);
        for _ in 0..3 {
            e.handle(&Request::Sample { theta: theta.clone(), count: 1 }, &mut rng);
        }
        assert_eq!(e.metrics.sample.count(), 3);
    }
}
