//! Typed request/response API of the inference service, with JSON codecs
//! for the TCP wire protocol.
//!
//! The service model mirrors the paper's amortized setting: the engine
//! owns one preprocessed database + MIPS index; every request carries its
//! own parameter vector θ.

use crate::error::{Error, Result};
use crate::util::json::Json;

/// A query against the engine.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    /// Draw `count` fresh samples from Pr(x) ∝ exp(θ·φ(x)) (Algorithm 1;
    /// one MIPS retrieval amortized across the batch).
    Sample { theta: Vec<f32>, count: usize },
    /// Retrieve the approximate top-k states by score.
    TopK { theta: Vec<f32>, k: usize },
    /// Estimate log Z(θ) (Algorithm 3).
    LogPartition { theta: Vec<f32> },
    /// Estimate E_θ[φ] and log Z (Algorithm 4).
    ExpectFeatures { theta: Vec<f32> },
    /// Exact-scan TV certificate for θ (§4.2.1; heavyweight audit).
    TvCertify { theta: Vec<f32> },
    /// Engine + metrics snapshot.
    Stats,
    /// Prometheus-text exposition of the obs registry (plus per-shard
    /// aggregation when serving `--remote`).
    Metrics,
}

impl Request {
    pub fn op_name(&self) -> &'static str {
        match self {
            Request::Sample { .. } => "sample",
            Request::TopK { .. } => "topk",
            Request::LogPartition { .. } => "log_partition",
            Request::ExpectFeatures { .. } => "expect_features",
            Request::TvCertify { .. } => "tv_certify",
            Request::Stats => "stats",
            Request::Metrics => "metrics",
        }
    }

    /// Parse from a JSON wire object `{"op": ..., ...}`.
    pub fn from_json(j: &Json) -> Result<Request> {
        let op = j.req("op")?.as_str()?;
        let theta = |j: &Json| -> Result<Vec<f32>> { j.req("theta")?.as_f32_vec() };
        Ok(match op {
            "sample" => Request::Sample {
                theta: theta(j)?,
                count: j.get("count").map(|c| c.as_usize()).transpose()?.unwrap_or(1),
            },
            "topk" => Request::TopK { theta: theta(j)?, k: j.req("k")?.as_usize()? },
            "log_partition" => Request::LogPartition { theta: theta(j)? },
            "expect_features" => Request::ExpectFeatures { theta: theta(j)? },
            "tv_certify" => Request::TvCertify { theta: theta(j)? },
            "stats" => Request::Stats,
            "metrics" => Request::Metrics,
            other => return Err(Error::serve(format!("unknown op '{other}'"))),
        })
    }

    pub fn to_json(&self) -> Json {
        match self {
            Request::Sample { theta, count } => Json::obj(vec![
                ("op", Json::str("sample")),
                ("theta", Json::arr_f32(theta)),
                ("count", Json::num(*count as f64)),
            ]),
            Request::TopK { theta, k } => Json::obj(vec![
                ("op", Json::str("topk")),
                ("theta", Json::arr_f32(theta)),
                ("k", Json::num(*k as f64)),
            ]),
            Request::LogPartition { theta } => Json::obj(vec![
                ("op", Json::str("log_partition")),
                ("theta", Json::arr_f32(theta)),
            ]),
            Request::ExpectFeatures { theta } => Json::obj(vec![
                ("op", Json::str("expect_features")),
                ("theta", Json::arr_f32(theta)),
            ]),
            Request::TvCertify { theta } => Json::obj(vec![
                ("op", Json::str("tv_certify")),
                ("theta", Json::arr_f32(theta)),
            ]),
            Request::Stats => Json::obj(vec![("op", Json::str("stats"))]),
            Request::Metrics => Json::obj(vec![("op", Json::str("metrics"))]),
        }
    }
}

/// Machine-readable serving health numbers carried alongside the
/// human-oriented [`Response::Stats`] text. All fields default to zero /
/// `false` when absent on the wire, so old and new peers interoperate.
#[derive(Clone, Copy, Debug, PartialEq, Default)]
pub struct StatsNumbers {
    /// tier-ladder certificate hit rate across all rungs (0..=1)
    pub certificate_hit_rate: f64,
    /// mean rows scanned per handled request
    pub scanned_rows_per_request: f64,
    /// requests currently waiting in the coordinator queue
    pub queue_depth: u64,
    /// requests shed by the front-end so far
    pub shed: u64,
    /// serving from a degraded snapshot (quantized shadow lost)
    pub snapshot_degraded: bool,
}

/// A query result.
#[derive(Clone, Debug, PartialEq)]
pub enum Response {
    Samples { ids: Vec<u32>, scanned: usize, tail_m: usize },
    TopK { ids: Vec<u32>, scores: Vec<f32> },
    LogPartition { log_z: f64, k: usize, l: usize },
    Features { mean: Vec<f32>, log_z: f64 },
    Tv { bound: f64 },
    Stats { text: String, numbers: StatsNumbers },
    /// Prometheus text-format exposition of the metrics registry.
    Metrics { exposition: String },
    /// A successful answer computed while some remote shards were
    /// unreachable: `inner` holds the result renormalized over the
    /// `ok_shards` surviving shards (of `shards` total). On the wire this
    /// is the inner object plus `"degraded": true` and
    /// `"shards_ok": "s/N"`, so clients that ignore the extra keys keep
    /// working and clients that care can tell partial answers apart.
    Degraded { inner: Box<Response>, ok_shards: usize, shards: usize },
    Error { message: String },
}

impl Response {
    pub fn to_json(&self) -> Json {
        match self {
            Response::Samples { ids, scanned, tail_m } => Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("ids", Json::Arr(ids.iter().map(|&i| Json::num(i as f64)).collect())),
                ("scanned", Json::num(*scanned as f64)),
                ("tail_m", Json::num(*tail_m as f64)),
            ]),
            Response::TopK { ids, scores } => Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("ids", Json::Arr(ids.iter().map(|&i| Json::num(i as f64)).collect())),
                ("scores", Json::arr_f32(scores)),
            ]),
            Response::LogPartition { log_z, k, l } => Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("log_z", Json::num(*log_z)),
                ("k", Json::num(*k as f64)),
                ("l", Json::num(*l as f64)),
            ]),
            Response::Features { mean, log_z } => Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("mean", Json::arr_f32(mean)),
                ("log_z", Json::num(*log_z)),
            ]),
            Response::Tv { bound } => {
                Json::obj(vec![("ok", Json::Bool(true)), ("tv_bound", Json::num(*bound))])
            }
            Response::Stats { text, numbers } => Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("stats", Json::str(text.clone())),
                ("certificate_hit_rate", Json::num(numbers.certificate_hit_rate)),
                ("scanned_rows_per_request", Json::num(numbers.scanned_rows_per_request)),
                ("queue_depth", Json::num(numbers.queue_depth as f64)),
                ("shed", Json::num(numbers.shed as f64)),
                ("snapshot_degraded", Json::Bool(numbers.snapshot_degraded)),
            ]),
            Response::Metrics { exposition } => Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("exposition", Json::str(exposition.clone())),
            ]),
            Response::Degraded { inner, ok_shards, shards } => {
                let mut j = inner.to_json();
                if let Json::Obj(kvs) = &mut j {
                    kvs.push(("degraded".to_string(), Json::Bool(true)));
                    kvs.push((
                        "shards_ok".to_string(),
                        Json::str(format!("{ok_shards}/{shards}")),
                    ));
                }
                j
            }
            Response::Error { message } => Json::obj(vec![
                ("ok", Json::Bool(false)),
                ("error", Json::str(message.clone())),
            ]),
        }
    }

    pub fn from_json(j: &Json) -> Result<Response> {
        let ok = j.req("ok")?.as_bool()?;
        if !ok {
            return Ok(Response::Error {
                message: j.get("error").and_then(|e| e.as_str().ok()).unwrap_or("?").to_string(),
            });
        }
        let body = Self::body_from_json(j)?;
        if j.get("degraded").map(|d| d.as_bool()).transpose()?.unwrap_or(false) {
            let (ok_shards, shards) = j
                .get("shards_ok")
                .and_then(|v| v.as_str().ok())
                .and_then(|s| s.split_once('/'))
                .and_then(|(a, b)| Some((a.parse().ok()?, b.parse().ok()?)))
                .unwrap_or((0, 0));
            return Ok(Response::Degraded { inner: Box::new(body), ok_shards, shards });
        }
        Ok(body)
    }

    /// The non-degraded payload probes, shared by [`Response::from_json`].
    fn body_from_json(j: &Json) -> Result<Response> {
        // "exposition" first: the metrics payload is arbitrary text and
        // must never be mistaken for another shape
        if let Some(e) = j.get("exposition") {
            return Ok(Response::Metrics { exposition: e.as_str()?.to_string() });
        }
        if let Some(b) = j.get("tv_bound") {
            return Ok(Response::Tv { bound: b.as_f64()? });
        }
        if let Some(s) = j.get("stats") {
            let f = |key: &str| j.get(key).and_then(|v| v.as_f64().ok()).unwrap_or(0.0);
            let numbers = StatsNumbers {
                certificate_hit_rate: f("certificate_hit_rate"),
                scanned_rows_per_request: f("scanned_rows_per_request"),
                queue_depth: f("queue_depth") as u64,
                shed: f("shed") as u64,
                snapshot_degraded: j
                    .get("snapshot_degraded")
                    .and_then(|v| v.as_bool().ok())
                    .unwrap_or(false),
            };
            return Ok(Response::Stats { text: s.as_str()?.to_string(), numbers });
        }
        if let Some(m) = j.get("mean") {
            return Ok(Response::Features {
                mean: m.as_f32_vec()?,
                log_z: j.req("log_z")?.as_f64()?,
            });
        }
        if let Some(lz) = j.get("log_z") {
            return Ok(Response::LogPartition {
                log_z: lz.as_f64()?,
                k: j.get("k").map(|v| v.as_usize()).transpose()?.unwrap_or(0),
                l: j.get("l").map(|v| v.as_usize()).transpose()?.unwrap_or(0),
            });
        }
        if let Some(s) = j.get("scores") {
            return Ok(Response::TopK {
                ids: j.req("ids")?.as_usize_vec()?.into_iter().map(|x| x as u32).collect(),
                scores: s.as_f32_vec()?,
            });
        }
        if let Some(ids) = j.get("ids") {
            return Ok(Response::Samples {
                ids: ids.as_usize_vec()?.into_iter().map(|x| x as u32).collect(),
                scanned: j.get("scanned").map(|v| v.as_usize()).transpose()?.unwrap_or(0),
                tail_m: j.get("tail_m").map(|v| v.as_usize()).transpose()?.unwrap_or(0),
            });
        }
        Err(Error::serve("unrecognized response shape"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip_req(r: Request) {
        let j = r.to_json();
        let back = Request::from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(back, r);
    }

    fn roundtrip_resp(r: Response) {
        let j = r.to_json();
        let back = Response::from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(back, r);
    }

    #[test]
    fn request_roundtrips() {
        roundtrip_req(Request::Sample { theta: vec![0.5, -1.0], count: 3 });
        roundtrip_req(Request::TopK { theta: vec![1.0], k: 7 });
        roundtrip_req(Request::LogPartition { theta: vec![2.0] });
        roundtrip_req(Request::ExpectFeatures { theta: vec![0.0, 0.25] });
        roundtrip_req(Request::TvCertify { theta: vec![1.5] });
        roundtrip_req(Request::Stats);
        roundtrip_req(Request::Metrics);
    }

    #[test]
    fn response_roundtrips() {
        roundtrip_resp(Response::Samples { ids: vec![1, 2, 3], scanned: 100, tail_m: 5 });
        roundtrip_resp(Response::TopK { ids: vec![9, 4], scores: vec![0.5, 0.25] });
        roundtrip_resp(Response::LogPartition { log_z: 12.5, k: 10, l: 20 });
        roundtrip_resp(Response::Features { mean: vec![0.5], log_z: 1.0 });
        roundtrip_resp(Response::Tv { bound: 1e-4 });
        roundtrip_resp(Response::Stats {
            text: "ok".into(),
            numbers: StatsNumbers {
                certificate_hit_rate: 0.75,
                scanned_rows_per_request: 128.0,
                queue_depth: 3,
                shed: 2,
                snapshot_degraded: true,
            },
        });
        roundtrip_resp(Response::Metrics {
            exposition: "# TYPE gmips_requests_total counter\ngmips_requests_total 4\n".into(),
        });
        roundtrip_resp(Response::Error { message: "boom".into() });
        roundtrip_resp(Response::Degraded {
            inner: Box::new(Response::LogPartition { log_z: 3.5, k: 4, l: 8 }),
            ok_shards: 3,
            shards: 4,
        });
        roundtrip_resp(Response::Degraded {
            inner: Box::new(Response::Samples { ids: vec![7], scanned: 40, tail_m: 1 }),
            ok_shards: 1,
            shards: 2,
        });
    }

    #[test]
    fn degraded_marks_the_wire_object() {
        let r = Response::Degraded {
            inner: Box::new(Response::Features { mean: vec![0.5], log_z: 1.0 }),
            ok_shards: 2,
            shards: 3,
        };
        let text = r.to_json().to_string();
        assert!(text.contains(r#""degraded":true"#), "{text}");
        assert!(text.contains(r#""shards_ok":"2/3""#), "{text}");
        // clients that ignore the extra keys still parse the payload
        let j = Json::parse(&text).unwrap();
        match Response::body_from_json(&j).unwrap() {
            Response::Features { mean, .. } => assert_eq!(mean, vec![0.5]),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn sample_count_defaults_to_one() {
        let j = Json::parse(r#"{"op":"sample","theta":[1,2]}"#).unwrap();
        match Request::from_json(&j).unwrap() {
            Request::Sample { count, .. } => assert_eq!(count, 1),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn stats_numbers_default_when_absent() {
        // an old peer sends only the text — numbers fall back to zero
        let j = Json::parse(r#"{"ok":true,"stats":"n=10"}"#).unwrap();
        match Response::from_json(&j).unwrap() {
            Response::Stats { text, numbers } => {
                assert_eq!(text, "n=10");
                assert_eq!(numbers, StatsNumbers::default());
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn unknown_op_rejected() {
        let j = Json::parse(r#"{"op":"nope"}"#).unwrap();
        assert!(Request::from_json(&j).is_err());
    }
}
