//! Product-quantization (PQ) screening codes — the most compressed tier
//! of the two-stage MIPS scan (Jégou et al. 2011; the screening-tier
//! framing follows Chen et al. 2018, "Learning to Screen for Fast
//! Softmax Inference", but kept **bit-exact** via the same
//! pass-2 + coverage-certificate contract as [`crate::linalg::quant`]).
//!
//! ## Encoding
//!
//! Rows are split into `m` subspaces of `dsub = d/m` dims. Each subspace
//! gets its own k-means codebook of `2^bits` centroids (trained by
//! [`crate::mips::kmeans`] on a deterministic row subsample), and every
//! row stores one code per subspace — `m` bytes/row at 8 bits,
//! `m/2` bytes/row at 4 bits, vs `4d` for f32. Codes are stored
//! **plane-major** (`codes[sub][row]`), so a contiguous scan reads `m`
//! sequential streams and the 4-bit kernels can table-gather 32 rows per
//! instruction.
//!
//! ## Asymmetric-distance scoring
//!
//! A query builds one lookup table per subspace,
//! `lut[sub][c] = q_sub · centroid[sub][c]`, so a row scores as the sum
//! of `m` table entries — no per-row arithmetic beyond the gather. The
//! f64 tables are quantized to **u8 with one shared step** `scale` and
//! per-subspace minima, which makes the hot sum pure integer:
//!
//! ```text
//! score ≈ Q = scale · Σ_sub lut_u8[sub][code] + Σ_sub lmin[sub]
//! ```
//!
//! The integer sum is what the SIMD kernels compute: at 4 bits each
//! subspace table is 16 bytes, so AVX2 `pshufb` / NEON `tbl` gathers 32
//! rows' entries per instruction into u16 lane accumulators (exact for
//! `m ≤ 256`); at 8 bits the gather is an unrolled scalar loop (a
//! 256-entry table exceeds the in-register shuffle width). Every kernel
//! produces the identical integer, and single-/multi-query entry points
//! share the per-row arithmetic, so batch output is bit-identical to
//! per-query calls.
//!
//! ## Error bound / certificate
//!
//! [`PqView::encode_query`] derives the per-query bound the coverage
//! certificate of [`crate::linalg::quant::coverage_proved`] consumes:
//!
//! ```text
//! |score − Q| ≤ Σ_sub ‖q_sub‖₂·maxres_sub   (Cauchy–Schwarz, reconstruction)
//!             + m · scale/2                  (LUT quantization)
//!             + fp slack                     (f32 kernel arithmetic)
//! ```
//!
//! where `maxres_sub` is the largest subspace residual norm over encoded
//! rows. The bound is far looser than SQ8's, so PQ certifies less often
//! — a miss rides the tier ladder (`mips::two_stage`) down to SQ8/f32
//! and correctness never depends on it firing.
//!
//! ## Fast-scan tiles (register-resident batched scan)
//!
//! The plane-major layout is optimal for one query but makes a batch
//! re-read every code byte per query: the [`PQ_CHUNK`] segments stay
//! L1-resident across the batch, yet each query still re-issues all the
//! loads and nibble unpacking. The FAISS-style **fast-scan** layout
//! re-blocks the 4-bit codes into [`FS_TILE`] = 32-row tiles, tile-major
//! `tiles[tile][sub][16 bytes]`: the 16 packed bytes a tile needs from
//! subspace `sub` sit contiguously, so a 4-query register block loads
//! and unpacks each subspace's codes **once** and runs four
//! `pshufb`/`tbl` gathers against them — codes stay in registers across
//! the query dimension, with u16 lane accumulators carried per query per
//! tile (exact for `m ≤ 256`, the same guard as the single-query
//! kernel). Because tile byte `b` is plane byte `16·tile + b` verbatim,
//! re-blocking is a pure copy and the integer sums are the **identical
//! integers** the plane kernels produce; the per-row affine conversion
//! is shared, so fast-scan output is bit-identical to the plane path on
//! every rung of the certificate contract (property-tested, and pinned
//! to the scalar reference under `GMIPS_FORCE_SCALAR`/Miri).
//!
//! [`PqView::scores_batch`] dispatches to the tiles for batches of
//! [`FS_MIN_BATCH`] = 4+ queries when the view carries them (4-bit
//! codes, `m ≤ 256`, `n ≥ 32`); ragged heads/tails of a row range and
//! leftover queries ride the plane path. Tiles persist as their own
//! snapshot section and are re-blocked in memory when absent (old
//! snapshots) — see `save_sections`/`open_sections`.

use crate::error::Result;
use crate::linalg::simd::{self, Kernel};
use crate::mips::kmeans;
use crate::store::blob::Blob;
use crate::store::format::{tag, ByteWriter, Snapshot, SnapshotWriter};

/// Rows per scoring chunk (keeps the u32 scratch on the stack and the
/// plane segments L1-resident across a batch's queries).
const PQ_CHUNK: usize = 256;

/// Rows per fast-scan tile: one 16-byte packed-nibble group per subspace
/// (32 rows × 4 bits = 16 bytes — exactly one `pshufb`/`tbl` shuffle).
pub const FS_TILE: usize = 32;

/// Smallest batch the fast-scan path serves: a 4-query register block is
/// the unit the tiled kernels amortize code loads over (module docs);
/// below it the plane path is the better schedule.
pub const FS_MIN_BATCH: usize = 4;

/// Product-quantized shadow copy of a row-major `[n × d]` f32 matrix.
#[derive(Clone, Debug)]
pub struct PqView {
    /// subspaces
    m: usize,
    /// dims per subspace = d/m
    dsub: usize,
    /// codebook slots per subspace = 2^bits (actual count in `csub`)
    k: usize,
    /// bits per code (4 or 8)
    bits: usize,
    n: usize,
    d: usize,
    /// centroids, `[m × k × dsub]` (unused slots zeroed)
    cents: Vec<f32>,
    /// trained centroids per subspace (≤ k; tiny datasets train fewer)
    csub: Vec<usize>,
    /// plane-major codes: bits=8 → `[m × n]`, bits=4 → `[m × ⌈n/2⌉]`
    /// nibble-packed (row r in byte r/2, even rows in the low nibble);
    /// owned or snapshot-mapped
    codes: Blob<u8>,
    /// bytes per plane
    stride: usize,
    /// fast-scan tile-major codes `[n/32 tiles × m subspaces × 16 bytes]`
    /// (module docs); empty when the view is not fast-scan eligible
    /// (bits ≠ 4, m > 256, or n < 32); owned or snapshot-mapped
    tiles: Blob<u8>,
    /// per-subspace max residual norm `max_r ‖x_sub − cent(code)‖₂`
    maxres: Vec<f32>,
    /// `max |x|` over the encoded matrix (fp-slack ingredient)
    max_abs: f32,
}

/// A query encoded for PQ screening: u8-quantized lookup tables plus the
/// exact offset/scale pair and the precomputed certificate bound.
#[derive(Clone, Debug)]
pub struct PqLut {
    /// u8 table entries, `[m × k]` (shared step, per-subspace minima)
    lut: Vec<u8>,
    /// shared LUT quantization step
    scale: f64,
    /// `Σ_sub lmin[sub]` — the error-free offset part of every score
    off_sum: f64,
    /// per-query error bound (module docs)
    eps: f32,
}

impl PqView {
    /// Train per-subspace codebooks on a deterministic stride-subsample
    /// of ≤ `train_n` rows and encode every row. `m` must divide `d`;
    /// `bits` ∈ {4, 8}. `iters` is clamped to [1, 10] (codebooks of 16
    /// or 256 sub-centroids converge in a handful of Lloyd steps).
    pub fn train(
        rows: &[f32],
        d: usize,
        m: usize,
        bits: usize,
        train_n: usize,
        iters: usize,
        seed: u64,
    ) -> PqView {
        assert!(m >= 1 && d > 0 && d % m == 0, "pq_m must divide d (got m={m}, d={d})");
        assert!(bits == 4 || bits == 8, "pq_bits must be 4 or 8 (got {bits})");
        let n = rows.len() / d;
        debug_assert_eq!(rows.len(), n * d);
        let dsub = d / m;
        let k = 1usize << bits;
        let stride = if bits == 4 { n.div_ceil(2) } else { n };
        let mut pv = PqView {
            m,
            dsub,
            k,
            bits,
            n,
            d,
            cents: vec![0f32; m * k * dsub],
            csub: vec![0usize; m],
            codes: vec![0u8; m * stride].into(),
            stride,
            tiles: Vec::new().into(),
            maxres: vec![0f32; m],
            max_abs: 0.0,
        };
        if n == 0 {
            return pv;
        }
        let tn = train_n.clamp(1, n);
        let step = n.div_ceil(tn);
        let picks: Vec<usize> = (0..n).step_by(step).collect();
        let mut train_buf = vec![0f32; picks.len() * dsub];
        let iters = iters.clamp(1, 10);
        for sub in 0..m {
            let off = sub * dsub;
            for (t, &r) in picks.iter().enumerate() {
                train_buf[t * dsub..(t + 1) * dsub]
                    .copy_from_slice(&rows[r * d + off..r * d + off + dsub]);
            }
            let km = kmeans::train(
                &train_buf,
                picks.len(),
                dsub,
                k.min(picks.len()),
                iters,
                seed ^ ((sub as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15)),
            );
            pv.csub[sub] = km.c;
            pv.cents[sub * k * dsub..sub * k * dsub + km.c * dsub]
                .copy_from_slice(&km.centroids);
        }
        pv.reencode(rows);
        pv
    }

    /// Re-encode every row against the **unchanged** codebooks — the
    /// compaction coherence hook (mirrors re-running the scalar views'
    /// `encode`; codebooks stay fixed like the IVF coarse quantizer).
    /// The nearest-centroid assignment pass is the whole cost of a PQ
    /// (re-)encode — `n·m·2^bits·dsub` distance terms — and each
    /// subspace owns its code plane and `maxres` entry, so the pass fans
    /// out across subspaces on the scoped pool.
    pub fn reencode(&mut self, rows: &[f32]) {
        debug_assert_eq!(rows.len(), self.n * self.d);
        if self.n == 0 {
            return;
        }
        self.max_abs = rows.iter().fold(0f32, |a, &x| a.max(x.abs()));
        let (n, d, m) = (self.n, self.d, self.m);
        let (dsub, k, bits, stride) = (self.dsub, self.k, self.bits, self.stride);
        let cents = &self.cents;
        let csub = &self.csub;
        // threads only pay off once the assignment pass is substantial
        let nthreads = if n * m * k >= (1 << 20) {
            crate::util::pool::default_threads().min(m)
        } else {
            1
        };
        let parts = crate::util::pool::parallel_chunks(m, nthreads, |_, s0, e0| {
            let mut planes = vec![0u8; (e0 - s0) * stride];
            let mut worsts = vec![0f32; e0 - s0];
            for sub in s0..e0 {
                let off = sub * dsub;
                let sc = &cents[sub * k * dsub..(sub + 1) * k * dsub];
                let cs = csub[sub];
                let plane = &mut planes[(sub - s0) * stride..(sub - s0 + 1) * stride];
                let mut worst = 0f64;
                for r in 0..n {
                    let v = &rows[r * d + off..r * d + off + dsub];
                    let (code, d2) = nearest(sc, cs, dsub, v);
                    worst = worst.max(d2);
                    if bits == 8 {
                        plane[r] = code;
                    } else if r % 2 == 0 {
                        plane[r / 2] = (plane[r / 2] & 0xf0) | code;
                    } else {
                        plane[r / 2] = (plane[r / 2] & 0x0f) | (code << 4);
                    }
                }
                worsts[sub - s0] = worst.sqrt() as f32;
            }
            (s0, planes, worsts)
        });
        let codes = self.codes.to_mut();
        for (s0, planes, worsts) in parts {
            let nsub = worsts.len();
            codes[s0 * stride..(s0 + nsub) * stride].copy_from_slice(&planes);
            self.maxres[s0..s0 + nsub].copy_from_slice(&worsts);
        }
        // re-block the fast-scan tiles against the fresh planes — the
        // compact()/update coherence hook for the tiled layout
        self.rebuild_tiles();
    }

    /// Whether this view's shape can carry fast-scan tiles: 4-bit codes
    /// (16-entry in-register LUT), `m ≤ 256` (exact u16 accumulators),
    /// and at least one full 32-row tile.
    fn fastscan_eligible(&self) -> bool {
        self.bits == 4 && self.m <= 256 && self.n >= FS_TILE
    }

    /// Bytes the fast-scan tile blob must hold: `⌊n/32⌋` tiles × m
    /// subspaces × 16 packed bytes.
    fn tile_bytes(&self) -> usize {
        (self.n / FS_TILE) * self.m * 16
    }

    /// Whether the tiled layout is present and will serve batches.
    pub fn fastscan_ready(&self) -> bool {
        !self.tiles.is_empty()
    }

    /// Whether [`scores_batch`](Self::scores_batch) will serve a batch of
    /// `nq` queries from the fast-scan tiles (the `layout` the obs
    /// counters attribute screened rows to).
    pub fn serves_fastscan(&self, nq: usize) -> bool {
        nq >= FS_MIN_BATCH && self.fastscan_ready()
    }

    /// (Re-)derive the tile-major fast-scan blob from the plane-major
    /// codes. Tile byte `b` of subspace `sub` is plane byte
    /// `sub·stride + 16·tile + b` **verbatim** — a tile starts at row
    /// `32·tile` (even), so the nibble phase of the packed bytes is
    /// unchanged and re-blocking is a pure gather copy; rows past the
    /// last full tile stay plane-only and ride the scalar/plane tail
    /// paths.
    fn rebuild_tiles(&mut self) {
        if !self.fastscan_eligible() {
            self.tiles = Vec::new().into();
            return;
        }
        let nt = self.n / FS_TILE;
        let mut t = vec![0u8; nt * self.m * 16];
        for ti in 0..nt {
            for sub in 0..self.m {
                let src = sub * self.stride + ti * 16;
                let dst = (ti * self.m + sub) * 16;
                t[dst..dst + 16].copy_from_slice(&self.codes[src..src + 16]);
            }
        }
        self.tiles = t.into();
    }

    /// Number of encoded rows.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Feature dimension.
    pub fn d(&self) -> usize {
        self.d
    }

    /// Number of subspaces.
    pub fn m(&self) -> usize {
        self.m
    }

    /// Bits per subspace code.
    pub fn bits(&self) -> usize {
        self.bits
    }

    #[inline]
    fn get_code(&self, sub: usize, r: usize) -> u8 {
        if self.bits == 8 {
            self.codes[sub * self.stride + r]
        } else {
            let b = self.codes[sub * self.stride + r / 2];
            if r % 2 == 0 {
                b & 0x0f
            } else {
                b >> 4
            }
        }
    }

    /// Build the per-query lookup tables and certificate bound.
    pub fn encode_query(&self, q: &[f32]) -> PqLut {
        debug_assert_eq!(q.len(), self.d);
        let (m, k, dsub) = (self.m, self.k, self.dsub);
        let mut lutf = vec![0f64; m * k];
        let mut lmin = vec![0f64; m];
        let mut span = 0f64;
        let mut res_term = 0f64;
        let l1: f64 = q.iter().map(|&x| x.abs() as f64).sum();
        for sub in 0..m {
            let qs = &q[sub * dsub..(sub + 1) * dsub];
            let cents = &self.cents[sub * k * dsub..(sub + 1) * k * dsub];
            let cs = self.csub[sub];
            let mut mn = 0f64;
            let mut mx = 0f64;
            for c in 0..cs {
                let cent = &cents[c * dsub..(c + 1) * dsub];
                let mut s = 0f64;
                for (a, b) in qs.iter().zip(cent) {
                    s += *a as f64 * *b as f64;
                }
                lutf[sub * k + c] = s;
                if c == 0 {
                    mn = s;
                    mx = s;
                } else {
                    mn = mn.min(s);
                    mx = mx.max(s);
                }
            }
            lmin[sub] = mn;
            span = span.max(mx - mn);
            let qn: f64 = qs.iter().map(|&a| a as f64 * a as f64).sum();
            res_term += qn.sqrt() * self.maxres[sub] as f64;
        }
        let scale = span / 255.0;
        let inv = if scale > 0.0 { 1.0 / scale } else { 0.0 };
        let mut lut = vec![0u8; m * k];
        let mut off_sum = 0f64;
        for sub in 0..m {
            off_sum += lmin[sub];
            for c in 0..self.csub[sub] {
                lut[sub * k + c] =
                    ((lutf[sub * k + c] - lmin[sub]) * inv).round().clamp(0.0, 255.0) as u8;
            }
        }
        let lut_err = m as f64 * scale * 0.5;
        let fp = (self.d as f64 + 2.0) * 1.2e-7 * self.max_abs as f64 * l1;
        let eps = ((res_term + lut_err + fp) * 1.05 + 1e-12) as f32;
        PqLut { lut, scale, off_sum, eps }
    }

    /// Uniform bound on `|exact score − PQ score|` for every encoded row
    /// against `lut` (derived in [`encode_query`](Self::encode_query)).
    pub fn error_bound(&self, lut: &PqLut) -> f32 {
        lut.eps
    }

    /// PQ approximate scores for rows `[row_start, row_end)`:
    /// `out[i] = Q_{row_start+i}` (module docs).
    pub fn scores(&self, row_start: usize, row_end: usize, lut: &PqLut, out: &mut [f32]) {
        debug_assert!(row_start <= row_end && row_end <= self.n);
        debug_assert_eq!(out.len(), row_end - row_start);
        debug_assert_eq!(lut.lut.len(), self.m * self.k);
        let mut acc = [0u32; PQ_CHUNK];
        let mut r = row_start;
        while r < row_end {
            let e = (r + PQ_CHUNK).min(row_end);
            let nr = e - r;
            self.accum_into(r, e, &lut.lut, &mut acc[..nr]);
            let base = r - row_start;
            for (o, &a) in out[base..base + nr].iter_mut().zip(&acc[..nr]) {
                *o = (lut.scale * a as f64 + lut.off_sum) as f32;
            }
            r = e;
        }
    }

    /// PQ scores for an explicit (gathered) id list — the scattered
    /// candidate-screening form; per-score arithmetic identical to
    /// [`scores`](Self::scores).
    pub fn scores_ids(&self, ids: &[u32], lut: &PqLut, out: &mut [f32]) {
        debug_assert_eq!(out.len(), ids.len());
        for (o, &id) in out.iter_mut().zip(ids) {
            let r = id as usize;
            debug_assert!(r < self.n);
            let mut s = 0u32;
            for sub in 0..self.m {
                s += lut.lut[sub * self.k + self.get_code(sub, r) as usize] as u32;
            }
            *o = (lut.scale * s as f64 + lut.off_sum) as f32;
        }
    }

    /// Multi-query PQ scores — query-major
    /// `out[j·nr + i] = Q_{row_start+i}(luts[j])`. Batches of
    /// [`FS_MIN_BATCH`]+ queries on a fast-scan-ready view serve from the
    /// register-resident tiles ([`scores_batch_fastscan`]); everything
    /// else takes the plane path. Both are bit-identical to per-query
    /// [`scores`](Self::scores) calls (module docs), so the dispatch is
    /// invisible to the certificate contract.
    pub fn scores_batch(
        &self,
        row_start: usize,
        row_end: usize,
        luts: &[&PqLut],
        out: &mut [f32],
    ) {
        if self.serves_fastscan(luts.len()) {
            self.scores_batch_fastscan(row_start, row_end, luts, out);
        } else {
            self.scores_batch_plane(row_start, row_end, luts, out);
        }
    }

    /// Plane-major multi-query scores: the whole batch works through each
    /// [`PQ_CHUNK`]-row segment of the (tiny) code planes while it is
    /// L1-resident, so codes stream from memory once per batch — but each
    /// query still re-issues the loads and nibble unpacking. Public so
    /// the perf bench can hold it against the tiled path.
    pub fn scores_batch_plane(
        &self,
        row_start: usize,
        row_end: usize,
        luts: &[&PqLut],
        out: &mut [f32],
    ) {
        debug_assert!(row_start <= row_end && row_end <= self.n);
        let nr = row_end - row_start;
        let nq = luts.len();
        debug_assert_eq!(out.len(), nq * nr);
        let mut acc = [0u32; PQ_CHUNK];
        let mut r = row_start;
        while r < row_end {
            let e = (r + PQ_CHUNK).min(row_end);
            let nrr = e - r;
            for (j, lut) in luts.iter().enumerate() {
                self.accum_into(r, e, &lut.lut, &mut acc[..nrr]);
                let base = j * nr + (r - row_start);
                for (o, &a) in out[base..base + nrr].iter_mut().zip(&acc[..nrr]) {
                    *o = (lut.scale * a as f64 + lut.off_sum) as f32;
                }
            }
            r = e;
        }
    }

    /// Fast-scan multi-query scores over the 32-row tiles (module docs):
    /// the tile-aligned middle of `[row_start, row_end)` is served per
    /// 4-query register block — each subspace's 16 code bytes are loaded
    /// and unpacked once per block and gathered against all four queries'
    /// LUTs with u16 lane accumulators carried across subspaces — while
    /// the ragged head/tail rows and any leftover (`nq mod 4`) queries
    /// ride the plane path. Integer sums equal the plane kernels' and the
    /// affine conversion is shared, so output is bit-identical to
    /// [`scores_batch_plane`](Self::scores_batch_plane).
    pub fn scores_batch_fastscan(
        &self,
        row_start: usize,
        row_end: usize,
        luts: &[&PqLut],
        out: &mut [f32],
    ) {
        debug_assert!(row_start <= row_end && row_end <= self.n);
        debug_assert!(self.fastscan_ready() && self.bits == 4);
        let nr = row_end - row_start;
        let nq = luts.len();
        debug_assert_eq!(out.len(), nq * nr);
        let tile_lo = row_start.next_multiple_of(FS_TILE);
        // full tiles only: rows past ⌊n/32⌋·32 have no tile at all
        let tile_hi = (row_end / FS_TILE) * FS_TILE;
        if tile_lo >= tile_hi {
            return self.scores_batch_plane(row_start, row_end, luts, out);
        }
        // ragged head/tail rows: plane path per query (bit-identical by
        // the shared integer/affine arithmetic)
        for (j, lut) in luts.iter().enumerate() {
            if row_start < tile_lo {
                let h = tile_lo - row_start;
                self.scores(row_start, tile_lo, lut, &mut out[j * nr..j * nr + h]);
            }
            if tile_hi < row_end {
                let o = tile_hi - row_start;
                self.scores(tile_hi, row_end, lut, &mut out[j * nr + o..j * nr + nr]);
            }
        }
        let groups = nq / 4 * 4;
        let mut sums = [0u32; 4 * FS_TILE];
        let tbytes = self.m * 16;
        for t in tile_lo / FS_TILE..tile_hi / FS_TILE {
            let base = t * FS_TILE - row_start;
            let tile = &self.tiles[t * tbytes..(t + 1) * tbytes];
            let mut j = 0;
            while j < groups {
                self.fs_accum_tile4(
                    tile,
                    [&luts[j].lut, &luts[j + 1].lut, &luts[j + 2].lut, &luts[j + 3].lut],
                    &mut sums,
                );
                for (g, lut) in luts[j..j + 4].iter().enumerate() {
                    let dst = (j + g) * nr + base;
                    let qsums = &sums[g * FS_TILE..(g + 1) * FS_TILE];
                    for (o, &a) in out[dst..dst + FS_TILE].iter_mut().zip(qsums) {
                        *o = (lut.scale * a as f64 + lut.off_sum) as f32;
                    }
                }
                j += 4;
            }
        }
        // leftover queries (nq mod 4) score the tiled middle on the
        // plane path
        for (j, lut) in luts.iter().enumerate().skip(groups) {
            let o0 = tile_lo - row_start;
            let o1 = tile_hi - row_start;
            self.scores(tile_lo, tile_hi, lut, &mut out[j * nr + o0..j * nr + o1]);
        }
    }

    /// Integer LUT sums of one fast-scan tile for a 4-query register
    /// block: `sums[qi·32 + r] = Σ_sub lut_qi[sub][code(row, sub)]` for
    /// the tile's 32 rows. Dispatches on the one-time CPU probe; every
    /// kernel computes the identical integers (and exactly the integers
    /// [`accum_scalar`](Self::accum_scalar) computes for the same rows).
    fn fs_accum_tile4(&self, tile: &[u8], luts: [&[u8]; 4], sums: &mut [u32; 4 * FS_TILE]) {
        debug_assert_eq!(tile.len(), self.m * 16);
        debug_assert_eq!(self.bits, 4);
        debug_assert!(self.m <= 256);
        debug_assert!(luts.iter().all(|l| l.len() >= self.m * self.k));
        match simd::kernel() {
            #[cfg(target_arch = "x86_64")]
            // SAFETY: avx2 verified by `simd::detect()`; the fast-scan
            // eligibility gate pins bits == 4 (k = 16-byte subspace LUTs)
            // and m ≤ 256 (exact u16 lanes); tile/LUT sizes are
            // debug-asserted above — the kernel's contract.
            Kernel::Avx2 => unsafe { fs_tile4_avx2(tile, self.m, self.k, luts, sums) },
            #[cfg(target_arch = "aarch64")]
            // SAFETY: NEON verified by `simd::detect()`; same
            // eligibility/size argument as the AVX2 arm.
            Kernel::Neon => unsafe { fs_tile4_neon(tile, self.m, self.k, luts, sums) },
            _ => fs_tile4_scalar(tile, self.m, self.k, luts, sums),
        }
    }

    /// Integer LUT sums for rows `[row_start, row_end)` into `acc`
    /// (overwritten). Dispatches the 4-bit table-gather kernels when the
    /// u16 lane accumulators cannot overflow (`m ≤ 256`); every kernel
    /// computes the identical integers.
    fn accum_into(&self, row_start: usize, row_end: usize, lut: &[u8], acc: &mut [u32]) {
        debug_assert_eq!(acc.len(), row_end - row_start);
        acc.iter_mut().for_each(|x| *x = 0);
        debug_assert!(row_start <= row_end && row_end <= self.n);
        debug_assert!(lut.len() >= self.m * self.k);
        match simd::kernel() {
            #[cfg(target_arch = "x86_64")]
            // SAFETY: avx2 verified by `simd::detect()`; the guard pins
            // bits == 4 (so each plane holds ⌈n/2⌉ packed bytes and each
            // subspace LUT is k = 16 bytes) and the row range / LUT sizes
            // are debug-asserted above — the kernel's contract.
            Kernel::Avx2 if self.bits == 4 && self.m <= 256 => unsafe {
                self.accum4_avx2(row_start, row_end, lut, acc)
            },
            #[cfg(target_arch = "aarch64")]
            // SAFETY: NEON verified by `simd::detect()`; same bits == 4 /
            // range-containment argument as the AVX2 arm.
            Kernel::Neon if self.bits == 4 && self.m <= 256 => unsafe {
                self.accum4_neon(row_start, row_end, lut, acc)
            },
            _ => self.accum_scalar(row_start, row_end, lut, acc),
        }
    }

    /// Scalar LUT gather (the dispatch fallback, the 8-bit path, and the
    /// test reference). Adds into `acc` over pre-zeroed entries.
    fn accum_scalar(&self, row_start: usize, row_end: usize, lut: &[u8], acc: &mut [u32]) {
        for sub in 0..self.m {
            let l = &lut[sub * self.k..(sub + 1) * self.k];
            let plane = &self.codes[sub * self.stride..(sub + 1) * self.stride];
            if self.bits == 8 {
                for (a, &c) in acc.iter_mut().zip(&plane[row_start..row_end]) {
                    *a += l[c as usize] as u32;
                }
            } else {
                for (i, r) in (row_start..row_end).enumerate() {
                    let b = plane[r / 2];
                    let c = if r % 2 == 0 { b & 0x0f } else { b >> 4 };
                    acc[i] += l[c as usize] as u32;
                }
            }
        }
    }

    /// AVX2 4-bit kernel: per subspace, `pshufb` gathers 32 rows' table
    /// entries from the 16-byte LUT in one shuffle; entries accumulate in
    /// u16 lanes (exact: `m ≤ 256` ⇒ sums ≤ 255·256 < 2¹⁶) and widen to
    /// u32 on store. Scalar prologue/epilogue handle the odd-row nibble
    /// phase and the ragged tail.
    ///
    /// # Safety
    /// Caller must guarantee AVX2 availability (guaranteed via
    /// [`crate::linalg::simd::kernel`]), `self.bits == 4` (so every code
    /// plane holds `stride = ⌈n/2⌉` packed bytes and every subspace LUT
    /// is `k = 16` bytes), `row_start ≤ row_end ≤ self.n`,
    /// `acc.len() == row_end − row_start`, and `lut.len() ≥ m·k`.
    // See `linalg::simd`'s `avx2` module for why `unused_unsafe` is
    // tolerated on the SIMD kernels.
    #[cfg(target_arch = "x86_64")]
    #[allow(unused_unsafe)]
    #[target_feature(enable = "avx2")]
    unsafe fn accum4_avx2(&self, row_start: usize, row_end: usize, lut: &[u8], acc: &mut [u32]) {
        use std::arch::x86_64::*;
        debug_assert!(std::arch::is_x86_feature_detected!("avx2"));
        debug_assert_eq!(self.bits, 4);
        debug_assert!(row_start <= row_end && row_end <= self.n);
        debug_assert_eq!(acc.len(), row_end - row_start);
        debug_assert!(lut.len() >= self.m * self.k);
        let mut r = row_start;
        if r % 2 == 1 && r < row_end {
            self.accum_scalar(r, r + 1, lut, &mut acc[..1]);
            r += 1;
        }
        // SAFETY: value-only constant splat.
        let mask = unsafe { _mm_set1_epi8(0x0f) };
        while r + 32 <= row_end {
            let base = r - row_start;
            // SAFETY: value-only accumulator zeroing.
            let mut a0 = unsafe { _mm256_setzero_si256() }; // rows r..r+16, u16 lanes
            let mut a1 = unsafe { _mm256_setzero_si256() }; // rows r+16..r+32
            for sub in 0..self.m {
                // SAFETY: r is even here and r + 32 ≤ row_end ≤ n, so the
                // 16-byte packed load covers bytes r/2..r/2+16 with
                // r/2 + 15 ≤ (row_end − 32)/2 + 15 < ⌈n/2⌉ = stride,
                // inside plane `sub` of the codes blob (m·stride bytes).
                let raw = unsafe {
                    _mm_loadu_si128(
                        self.codes.as_ptr().add(sub * self.stride + r / 2).cast::<__m128i>(),
                    )
                };
                // SAFETY: the 16-byte LUT load reads lut[sub·k..sub·k+16]
                // with k = 16 and lut.len() ≥ m·k; the shuffle/unpack/
                // widen/add chain is value-only.
                unsafe {
                    let lo = _mm_and_si128(raw, mask);
                    let hi = _mm_and_si128(_mm_srli_epi16::<4>(raw), mask);
                    let tbl =
                        _mm_loadu_si128(lut.as_ptr().add(sub * self.k).cast::<__m128i>());
                    let tlo = _mm_shuffle_epi8(tbl, lo);
                    let thi = _mm_shuffle_epi8(tbl, hi);
                    let even = _mm_unpacklo_epi8(tlo, thi); // rows r..r+16 in order
                    let odd = _mm_unpackhi_epi8(tlo, thi); // rows r+16..r+32
                    a0 = _mm256_add_epi16(a0, _mm256_cvtepu8_epi16(even));
                    a1 = _mm256_add_epi16(a1, _mm256_cvtepu8_epi16(odd));
                }
            }
            // SAFETY: `store_u16_as_u32` writes 16 u32 each at `base` and
            // `base + 16`; the largest index touched is base + 31 =
            // r + 31 − row_start ≤ row_end − 1 − row_start < acc.len().
            unsafe {
                store_u16_as_u32(a0, acc.as_mut_ptr().add(base));
                store_u16_as_u32(a1, acc.as_mut_ptr().add(base + 16));
            }
            r += 32;
        }
        if r < row_end {
            let base = r - row_start;
            self.accum_scalar(r, row_end, lut, &mut acc[base..]);
        }
    }

    /// NEON 4-bit kernel: `tbl` (vqtbl1q) gathers 32 rows' entries per
    /// subspace from the 16-byte LUT; u16 widening accumulate, u32 store.
    ///
    /// # Safety
    /// Same contract as [`accum4_avx2`](Self::accum4_avx2) with NEON in
    /// place of AVX2.
    // See `linalg::simd`'s `avx2` module for why `unused_unsafe` is
    // tolerated on the SIMD kernels.
    #[cfg(target_arch = "aarch64")]
    #[allow(unused_unsafe)]
    #[target_feature(enable = "neon")]
    unsafe fn accum4_neon(&self, row_start: usize, row_end: usize, lut: &[u8], acc: &mut [u32]) {
        use std::arch::aarch64::*;
        debug_assert!(std::arch::is_aarch64_feature_detected!("neon"));
        debug_assert_eq!(self.bits, 4);
        debug_assert!(row_start <= row_end && row_end <= self.n);
        debug_assert_eq!(acc.len(), row_end - row_start);
        debug_assert!(lut.len() >= self.m * self.k);
        let mut r = row_start;
        if r % 2 == 1 && r < row_end {
            self.accum_scalar(r, r + 1, lut, &mut acc[..1]);
            r += 1;
        }
        while r + 32 <= row_end {
            let base = r - row_start;
            // SAFETY: value-only accumulator zeroing.
            let mut a = unsafe { [vdupq_n_u16(0); 4] }; // rows r+0..8, 8..16, 16..24, 24..32
            for sub in 0..self.m {
                // SAFETY: r is even and r + 32 ≤ row_end ≤ n, so the
                // 16-byte packed load covers bytes r/2..r/2+16 with
                // r/2 + 15 < ⌈n/2⌉ = stride inside plane `sub`; the LUT
                // load reads lut[sub·k..sub·k+16] with k = 16 and
                // lut.len() ≥ m·k; the tbl/zip/widening-add chain is
                // value-only.
                unsafe {
                    let raw = vld1q_u8(self.codes.as_ptr().add(sub * self.stride + r / 2));
                    let lo = vandq_u8(raw, vdupq_n_u8(0x0f));
                    let hi = vshrq_n_u8::<4>(raw);
                    let tbl = vld1q_u8(lut.as_ptr().add(sub * self.k));
                    let tlo = vqtbl1q_u8(tbl, lo);
                    let thi = vqtbl1q_u8(tbl, hi);
                    let even = vzip1q_u8(tlo, thi); // rows r..r+16 in order
                    let odd = vzip2q_u8(tlo, thi); // rows r+16..r+32
                    a[0] = vaddw_u8(a[0], vget_low_u8(even));
                    a[1] = vaddw_u8(a[1], vget_high_u8(even));
                    a[2] = vaddw_u8(a[2], vget_low_u8(odd));
                    a[3] = vaddw_u8(a[3], vget_high_u8(odd));
                }
            }
            for (t, &av) in a.iter().enumerate() {
                // SAFETY: the two 4-lane stores per accumulator write
                // acc[base + t·8 .. base + t·8 + 8]; the largest index is
                // base + 31 < acc.len() (see the loop bound r + 32 ≤
                // row_end and acc.len() == row_end − row_start).
                unsafe {
                    vst1q_u32(acc.as_mut_ptr().add(base + t * 8), vmovl_u16(vget_low_u16(av)));
                    vst1q_u32(
                        acc.as_mut_ptr().add(base + t * 8 + 4),
                        vmovl_u16(vget_high_u16(av)),
                    );
                }
            }
            r += 32;
        }
        if r < row_end {
            let base = r - row_start;
            self.accum_scalar(r, row_end, lut, &mut acc[base..]);
        }
    }
}

// ---------------------------------------------------------------------------
// snapshot persistence (crate::store)
// ---------------------------------------------------------------------------

impl PqView {
    /// Write this view as `PQ_META` + `PQ_CODES` (+ `PQ_TILES` when the
    /// fast-scan layout is carried) sections under `arg`. The tiles
    /// section is *optional* by design: snapshots from before the tiled
    /// layout lack it and still open (see
    /// [`open_sections`](Self::open_sections)), so the section tag is the
    /// format's version gate — no header-version bump, old files never
    /// error.
    pub(crate) fn save_sections(&self, w: &mut SnapshotWriter, arg: u32) -> Result<()> {
        let mut m = ByteWriter::default();
        m.u64(self.m as u64);
        m.u64(self.dsub as u64);
        m.u64(self.k as u64);
        m.u64(self.bits as u64);
        m.u64(self.n as u64);
        m.u64(self.d as u64);
        m.u64(self.stride as u64);
        m.f32(self.max_abs);
        let csub: Vec<u64> = self.csub.iter().map(|&c| c as u64).collect();
        m.slice(&csub);
        m.slice(&self.maxres);
        m.slice(&self.cents);
        w.section(tag::PQ_META, arg, m.bytes())?;
        w.section(tag::PQ_CODES, arg, &self.codes)?;
        if self.fastscan_ready() {
            w.section(tag::PQ_TILES, arg, &self.tiles)?;
        }
        Ok(())
    }

    /// Reopen from a snapshot; the code planes (and fast-scan tiles)
    /// serve zero-copy when the snapshot is mapped. `None` when the
    /// META/CODES sections are missing, corrupt, or shape-inconsistent —
    /// the tier ladder then degrades. The `PQ_TILES` section is **soft in
    /// a stronger sense**: a snapshot written before the tiled layout (or
    /// with a corrupt/mis-shaped tiles section) re-blocks the tiles in
    /// memory from the validated plane codes — a one-time migration, with
    /// bit-identical answers, never an error and never a degrade.
    pub(crate) fn open_sections(snap: &Snapshot, arg: u32) -> Option<PqView> {
        let mut r = snap.reader_soft(tag::PQ_META, arg)?;
        let m = r.usize().ok()?;
        let dsub = r.usize().ok()?;
        let k = r.usize().ok()?;
        let bits = r.usize().ok()?;
        let n = r.usize().ok()?;
        let d = r.usize().ok()?;
        let stride = r.usize().ok()?;
        let max_abs = r.f32().ok()?;
        let csub64: Vec<u64> = r.vec().ok()?;
        let maxres: Vec<f32> = r.vec().ok()?;
        let cents: Vec<f32> = r.vec().ok()?;
        let codes: Blob<u8> = snap.blob_soft(tag::PQ_CODES, arg)?;
        if !(bits == 4 || bits == 8)
            || m == 0
            || k != 1usize << bits
            || m.checked_mul(dsub)? != d
            || stride != if bits == 4 { n.div_ceil(2) } else { n }
        {
            return None;
        }
        let csub: Vec<usize> = csub64.iter().map(|&c| c as usize).collect();
        if csub.len() != m
            || maxres.len() != m
            || cents.len() != m.checked_mul(k)?.checked_mul(dsub)?
            || codes.len() != m.checked_mul(stride)?
            || csub.iter().any(|&c| c > k)
        {
            return None;
        }
        let mut pv = PqView {
            m,
            dsub,
            k,
            bits,
            n,
            d,
            cents,
            csub,
            codes,
            stride,
            tiles: Vec::new().into(),
            maxres,
            max_abs,
        };
        if pv.fastscan_eligible() {
            match snap.blob_soft(tag::PQ_TILES, arg) {
                Some(t) if t.len() == pv.tile_bytes() => pv.tiles = t,
                // pre-tiles snapshot, or a corrupt/mis-shaped tiles
                // section: one-time in-memory re-block from the plane
                // codes (the migration path — never an error)
                _ => pv.rebuild_tiles(),
            }
        }
        Some(pv)
    }
}

/// Widen 16 u16 lanes to u32 and store (AVX2 helper).
///
/// # Safety
/// Caller must guarantee AVX2 availability and that `dst` is valid for
/// 16 u32 writes (`dst..dst + 16`).
// See `linalg::simd`'s `avx2` module for why `unused_unsafe` is
// tolerated on the SIMD kernels.
#[cfg(target_arch = "x86_64")]
#[allow(unused_unsafe)]
#[target_feature(enable = "avx2")]
unsafe fn store_u16_as_u32(v: std::arch::x86_64::__m256i, dst: *mut u32) {
    use std::arch::x86_64::*;
    // SAFETY: lane split/widen are value-only; the two unaligned 8-lane
    // stores cover exactly dst..dst+16, valid per this fn's contract.
    unsafe {
        let lo = _mm256_castsi256_si128(v);
        let hi = _mm256_extracti128_si256::<1>(v);
        _mm256_storeu_si256(dst.cast::<__m256i>(), _mm256_cvtepu16_epi32(lo));
        _mm256_storeu_si256(dst.add(8).cast::<__m256i>(), _mm256_cvtepu16_epi32(hi));
    }
}

/// Scalar fast-scan tile kernel — the dispatch fallback and the test /
/// Miri reference. Tile byte `b` of subspace group `sub` packs rows
/// `2b` (low nibble) and `2b + 1` (high nibble) of the tile, exactly as
/// the plane bytes it was copied from, so each sum is the same integer
/// [`PqView::accum_scalar`] computes for that row.
fn fs_tile4_scalar(
    tile: &[u8],
    m: usize,
    k: usize,
    luts: [&[u8]; 4],
    sums: &mut [u32; 4 * FS_TILE],
) {
    debug_assert_eq!(tile.len(), m * 16);
    sums.fill(0);
    for sub in 0..m {
        let grp = &tile[sub * 16..sub * 16 + 16];
        for (qi, lut) in luts.iter().enumerate() {
            let l = &lut[sub * k..sub * k + 16];
            let s = &mut sums[qi * FS_TILE..(qi + 1) * FS_TILE];
            for (b, &byte) in grp.iter().enumerate() {
                s[2 * b] += l[(byte & 0x0f) as usize] as u32;
                s[2 * b + 1] += l[(byte >> 4) as usize] as u32;
            }
        }
    }
}

/// AVX2 fast-scan tile kernel: per subspace, ONE 16-byte code load +
/// nibble unpack feeds FOUR `pshufb` LUT gathers — codes stay in
/// registers across the query dimension. Eight u16-lane accumulators
/// (2 per query: rows 0..16 / 16..32) are carried across all `m`
/// subspaces (exact: `m ≤ 256` ⇒ sums ≤ 255·256 < 2¹⁶) and widen to u32
/// on store. The unpack order matches [`PqView::accum4_avx2`], so per-row
/// integers equal the single-query kernel's.
///
/// # Safety
/// Caller must guarantee AVX2 availability (guaranteed via
/// [`crate::linalg::simd::kernel`]), `tile.len() == m·16`, `k == 16`
/// (4-bit codes), `m ≤ 256`, and every LUT valid for `m·k` byte reads.
// See `linalg::simd`'s `avx2` module for why `unused_unsafe` is
// tolerated on the SIMD kernels.
#[cfg(target_arch = "x86_64")]
#[allow(unused_unsafe)]
#[target_feature(enable = "avx2")]
unsafe fn fs_tile4_avx2(
    tile: &[u8],
    m: usize,
    k: usize,
    luts: [&[u8]; 4],
    sums: &mut [u32; 4 * FS_TILE],
) {
    use std::arch::x86_64::*;
    debug_assert!(std::arch::is_x86_feature_detected!("avx2"));
    debug_assert_eq!(tile.len(), m * 16);
    debug_assert_eq!(k, 16);
    debug_assert!(m <= 256);
    debug_assert!(luts.iter().all(|l| l.len() >= m * k));
    // SAFETY: value-only constant splat / accumulator zeroing.
    let mask = unsafe { _mm_set1_epi8(0x0f) };
    // SAFETY: value-only accumulator zeroing.
    let mut acc = unsafe { [[_mm256_setzero_si256(); 2]; 4] };
    for sub in 0..m {
        // SAFETY: tile.len() == m·16, so the 16-byte load at sub·16 reads
        // bytes sub·16..sub·16+16 ≤ m·16 — in bounds; the nibble split is
        // value-only.
        let (lo, hi) = unsafe {
            let raw = _mm_loadu_si128(tile.as_ptr().add(sub * 16).cast::<__m128i>());
            (_mm_and_si128(raw, mask), _mm_and_si128(_mm_srli_epi16::<4>(raw), mask))
        };
        for (qi, lut) in luts.iter().enumerate() {
            // SAFETY: the 16-byte LUT load reads lut[sub·k..sub·k+16]
            // with k = 16 and lut.len() ≥ m·k; the shuffle/unpack/widen/
            // add chain is value-only.
            unsafe {
                let tbl = _mm_loadu_si128(lut.as_ptr().add(sub * k).cast::<__m128i>());
                let tlo = _mm_shuffle_epi8(tbl, lo);
                let thi = _mm_shuffle_epi8(tbl, hi);
                let even = _mm_unpacklo_epi8(tlo, thi); // tile rows 0..16 in order
                let odd = _mm_unpackhi_epi8(tlo, thi); // tile rows 16..32
                acc[qi][0] = _mm256_add_epi16(acc[qi][0], _mm256_cvtepu8_epi16(even));
                acc[qi][1] = _mm256_add_epi16(acc[qi][1], _mm256_cvtepu8_epi16(odd));
            }
        }
    }
    for (qi, a) in acc.iter().enumerate() {
        // SAFETY: `store_u16_as_u32` writes 16 u32 each at qi·32 and
        // qi·32 + 16; the largest index touched is 3·32 + 31 = 127 <
        // sums.len() = 128.
        unsafe {
            store_u16_as_u32(a[0], sums.as_mut_ptr().add(qi * FS_TILE));
            store_u16_as_u32(a[1], sums.as_mut_ptr().add(qi * FS_TILE + 16));
        }
    }
}

/// NEON fast-scan tile kernel: one `vqtbl1q` source load per subspace
/// serves four queries' table gathers; sixteen u16 accumulators (4 per
/// query) carried across subspaces, widened to u32 on store. Unzip order
/// matches [`PqView::accum4_neon`].
///
/// # Safety
/// Same contract as [`fs_tile4_avx2`] with NEON in place of AVX2.
// See `linalg::simd`'s `avx2` module for why `unused_unsafe` is
// tolerated on the SIMD kernels.
#[cfg(target_arch = "aarch64")]
#[allow(unused_unsafe)]
#[target_feature(enable = "neon")]
unsafe fn fs_tile4_neon(
    tile: &[u8],
    m: usize,
    k: usize,
    luts: [&[u8]; 4],
    sums: &mut [u32; 4 * FS_TILE],
) {
    use std::arch::aarch64::*;
    debug_assert!(std::arch::is_aarch64_feature_detected!("neon"));
    debug_assert_eq!(tile.len(), m * 16);
    debug_assert_eq!(k, 16);
    debug_assert!(m <= 256);
    debug_assert!(luts.iter().all(|l| l.len() >= m * k));
    // SAFETY: value-only accumulator zeroing.
    let mut acc = unsafe { [[vdupq_n_u16(0); 4]; 4] };
    for sub in 0..m {
        // SAFETY: tile.len() == m·16, so the 16-byte load at sub·16 is in
        // bounds; the nibble split is value-only.
        let (lo, hi) = unsafe {
            let raw = vld1q_u8(tile.as_ptr().add(sub * 16));
            (vandq_u8(raw, vdupq_n_u8(0x0f)), vshrq_n_u8::<4>(raw))
        };
        for (qi, lut) in luts.iter().enumerate() {
            // SAFETY: the LUT load reads lut[sub·k..sub·k+16] with k = 16
            // and lut.len() ≥ m·k; the tbl/zip/widening-add chain is
            // value-only.
            unsafe {
                let tbl = vld1q_u8(lut.as_ptr().add(sub * k));
                let tlo = vqtbl1q_u8(tbl, lo);
                let thi = vqtbl1q_u8(tbl, hi);
                let even = vzip1q_u8(tlo, thi); // tile rows 0..16 in order
                let odd = vzip2q_u8(tlo, thi); // tile rows 16..32
                acc[qi][0] = vaddw_u8(acc[qi][0], vget_low_u8(even));
                acc[qi][1] = vaddw_u8(acc[qi][1], vget_high_u8(even));
                acc[qi][2] = vaddw_u8(acc[qi][2], vget_low_u8(odd));
                acc[qi][3] = vaddw_u8(acc[qi][3], vget_high_u8(odd));
            }
        }
    }
    for (qi, a) in acc.iter().enumerate() {
        for (t, &av) in a.iter().enumerate() {
            // SAFETY: the two 4-lane stores per accumulator write
            // sums[qi·32 + t·8 .. qi·32 + t·8 + 8]; the largest index is
            // 3·32 + 3·8 + 7 = 127 < sums.len() = 128.
            unsafe {
                vst1q_u32(sums.as_mut_ptr().add(qi * FS_TILE + t * 8), vmovl_u16(vget_low_u16(av)));
                vst1q_u32(
                    sums.as_mut_ptr().add(qi * FS_TILE + t * 8 + 4),
                    vmovl_u16(vget_high_u16(av)),
                );
            }
        }
    }
}

/// Nearest centroid among the first `cs` of `cents` (L2), returning
/// `(code, squared distance)` — the assignment step of encoding.
fn nearest(cents: &[f32], cs: usize, dsub: usize, v: &[f32]) -> (u8, f64) {
    let mut best = 0usize;
    let mut bd = f64::INFINITY;
    for c in 0..cs {
        let cent = &cents[c * dsub..(c + 1) * dsub];
        let mut s = 0f64;
        for (x, y) in v.iter().zip(cent) {
            let df = (x - y) as f64;
            s += df * df;
        }
        if s < bd {
            bd = s;
            best = c;
        }
    }
    (best as u8, bd)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg;
    use crate::util::check::Checker;
    use crate::util::rng::Pcg64;

    fn random_rows(n: usize, d: usize, seed: u64) -> Vec<f32> {
        let mut rng = Pcg64::new(seed);
        (0..n * d).map(|_| rng.gaussian() as f32).collect()
    }

    #[test]
    fn property_pq_error_bound_holds_per_row() {
        // the certificate contract: |exact − Q| ≤ ε for EVERY row, across
        // dims, subspace counts, and both code widths
        Checker::new(61).cases(40).check_u64(1u64 << 32, |seed| {
            let mut rng = Pcg64::new(seed ^ 0x90);
            let n = 50 + rng.next_below(300) as usize;
            let dsub = 1 + rng.next_below(6) as usize;
            let m = 1 + rng.next_below(8) as usize;
            let d = m * dsub;
            let rows = random_rows(n, d, seed);
            let q: Vec<f32> = (0..d).map(|_| rng.gaussian() as f32).collect();
            for bits in [4usize, 8] {
                let pv = PqView::train(&rows, d, m, bits, n, 5, seed);
                let lut = pv.encode_query(&q);
                let eps = pv.error_bound(&lut) as f64;
                let mut out = vec![0f32; n];
                pv.scores(0, n, &lut, &mut out);
                for r in 0..n {
                    let exact = linalg::dot(&rows[r * d..(r + 1) * d], &q) as f64;
                    if (exact - out[r] as f64).abs() > eps {
                        return false;
                    }
                }
            }
            true
        });
    }

    #[test]
    fn simd_accum_matches_scalar_on_ragged_ranges() {
        // the 4-bit table-gather kernel vs the scalar reference, across
        // odd starts (nibble phase), 32-row blocks, and ragged tails
        let (n, d, m) = (301usize, 16usize, 8usize);
        let rows = random_rows(n, d, 7);
        let pv = PqView::train(&rows, d, m, 4, n, 4, 9);
        let mut rng = Pcg64::new(11);
        let q: Vec<f32> = (0..d).map(|_| rng.gaussian() as f32).collect();
        let lut = pv.encode_query(&q);
        for (s, e) in [(0usize, 301usize), (1, 300), (3, 36), (0, 31), (32, 96), (299, 301)] {
            let mut got = vec![0u32; e - s];
            pv.accum_into(s, e, &lut.lut, &mut got);
            let mut want = vec![0u32; e - s];
            pv.accum_scalar(s, e, &lut.lut, &mut want);
            assert_eq!(got, want, "range=({s},{e})");
        }
    }

    #[test]
    fn scores_forms_are_bit_identical() {
        // contiguous, scattered, and batched scoring must agree bit for
        // bit on the same rows for both code widths
        let (n, d, m) = (150usize, 12usize, 4usize);
        let rows = random_rows(n, d, 3);
        let mut rng = Pcg64::new(5);
        for bits in [4usize, 8] {
            let pv = PqView::train(&rows, d, m, bits, n, 4, 13);
            let qs: Vec<Vec<f32>> = (0..5)
                .map(|_| (0..d).map(|_| rng.gaussian() as f32).collect())
                .collect();
            let luts: Vec<PqLut> = qs.iter().map(|q| pv.encode_query(q)).collect();
            let refs: Vec<&PqLut> = luts.iter().collect();
            let mut batch = vec![0f32; 5 * n];
            pv.scores_batch(0, n, &refs, &mut batch);
            for (j, lut) in luts.iter().enumerate() {
                let mut single = vec![0f32; n];
                pv.scores(0, n, lut, &mut single);
                for (a, b) in batch[j * n..(j + 1) * n].iter().zip(&single) {
                    assert_eq!(a.to_bits(), b.to_bits(), "bits={bits} q={j}");
                }
                let ids: Vec<u32> = (0..n as u32).rev().collect();
                let mut scattered = vec![0f32; n];
                pv.scores_ids(&ids, lut, &mut scattered);
                for (i, &id) in ids.iter().enumerate() {
                    assert_eq!(
                        scattered[i].to_bits(),
                        single[id as usize].to_bits(),
                        "bits={bits} q={j} id={id}"
                    );
                }
            }
        }
    }

    #[test]
    fn reencode_after_row_change_restores_bound() {
        // rewriting rows and re-encoding must keep the bound sound for
        // the new contents (codebooks unchanged)
        let (n, d, m) = (80usize, 8usize, 4usize);
        let mut rows = random_rows(n, d, 21);
        let mut pv = PqView::train(&rows, d, m, 4, n, 4, 23);
        let mut rng = Pcg64::new(25);
        for x in rows[10 * d..14 * d].iter_mut() {
            *x = 3.0 + rng.gaussian() as f32; // far outside the codebooks
        }
        pv.reencode(&rows);
        let q: Vec<f32> = (0..d).map(|_| rng.gaussian() as f32).collect();
        let lut = pv.encode_query(&q);
        let eps = pv.error_bound(&lut) as f64;
        let mut out = vec![0f32; n];
        pv.scores(0, n, &lut, &mut out);
        for r in 0..n {
            let exact = linalg::dot(&rows[r * d..(r + 1) * d], &q) as f64;
            assert!((exact - out[r] as f64).abs() <= eps, "row {r}");
        }
    }

    #[test]
    fn miri_pq_scalar_accum_and_bound_small() {
        // Miri-lane subset (scalar kernel pinned by cfg(miri)): the 4-bit
        // nibble gather and the certificate bound on a tiny instance
        let (n, d, m) = (37usize, 8usize, 4usize);
        let rows = random_rows(n, d, 31);
        let mut rng = Pcg64::new(33);
        let q: Vec<f32> = (0..d).map(|_| rng.gaussian() as f32).collect();
        for bits in [4usize, 8] {
            let pv = PqView::train(&rows, d, m, bits, n, 2, 35);
            let lut = pv.encode_query(&q);
            let eps = pv.error_bound(&lut) as f64;
            let mut out = vec![0f32; n];
            pv.scores(0, n, &lut, &mut out);
            for r in 0..n {
                let exact = linalg::dot(&rows[r * d..(r + 1) * d], &q) as f64;
                assert!((exact - out[r] as f64).abs() <= eps, "bits={bits} row={r}");
            }
            // odd start exercises the nibble-phase prologue
            let mut a = vec![0u32; 5];
            pv.accum_into(1, 6, &lut.lut, &mut a);
            let mut w = vec![0u32; 5];
            pv.accum_scalar(1, 6, &lut.lut, &mut w);
            assert_eq!(a, w, "bits={bits}");
        }
    }

    #[test]
    fn fastscan_batch_bit_identical_to_plane() {
        // the tentpole contract: the tiled path must produce bit-identical
        // f32 scores to the plane-major batch path, across ragged row
        // ranges (unaligned starts/ends — tile boundaries hit mid-range),
        // batch sizes around the 4-query register block, and n not a
        // multiple of the tile height
        let (n, d, m) = (301usize, 16usize, 8usize);
        let rows = random_rows(n, d, 41);
        let pv = PqView::train(&rows, d, m, 4, n, 4, 43);
        assert!(pv.fastscan_ready());
        let mut rng = Pcg64::new(45);
        let qs: Vec<Vec<f32>> = (0..9)
            .map(|_| (0..d).map(|_| rng.gaussian() as f32).collect())
            .collect();
        let luts: Vec<PqLut> = qs.iter().map(|q| pv.encode_query(q)).collect();
        for nq in [4usize, 5, 8, 9] {
            let refs: Vec<&PqLut> = luts[..nq].iter().collect();
            for (s, e) in [(0usize, 301usize), (1, 300), (17, 290), (0, 64), (31, 33), (64, 96)] {
                let nr = e - s;
                let mut fast = vec![0f32; nq * nr];
                pv.scores_batch_fastscan(s, e, &refs, &mut fast);
                let mut plane = vec![0f32; nq * nr];
                pv.scores_batch_plane(s, e, &refs, &mut plane);
                for (i, (a, b)) in fast.iter().zip(&plane).enumerate() {
                    assert_eq!(a.to_bits(), b.to_bits(), "nq={nq} range=({s},{e}) i={i}");
                }
            }
        }
    }

    #[test]
    fn fastscan_dispatch_and_eligibility() {
        // scores_batch must route 4+-query batches through the tiles and
        // smaller batches / ineligible shapes through the plane path,
        // with identical bits either way; 8-bit and tiny-n views carry no
        // tiles at all
        let (n, d, m) = (96usize, 8usize, 4usize);
        let rows = random_rows(n, d, 51);
        let pv = PqView::train(&rows, d, m, 4, n, 4, 53);
        assert!(pv.serves_fastscan(4) && !pv.serves_fastscan(3));
        let pv8 = PqView::train(&rows, d, m, 8, n, 4, 53);
        assert!(!pv8.fastscan_ready());
        let tiny = PqView::train(&rows[..16 * d], d, m, 4, 16, 4, 53);
        assert!(!tiny.fastscan_ready());
        let mut rng = Pcg64::new(55);
        let qs: Vec<Vec<f32>> = (0..4)
            .map(|_| (0..d).map(|_| rng.gaussian() as f32).collect())
            .collect();
        let luts: Vec<PqLut> = qs.iter().map(|q| pv.encode_query(q)).collect();
        let refs: Vec<&PqLut> = luts.iter().collect();
        let mut auto = vec![0f32; 4 * n];
        pv.scores_batch(0, n, &refs, &mut auto);
        let mut plane = vec![0f32; 4 * n];
        pv.scores_batch_plane(0, n, &refs, &mut plane);
        for (a, b) in auto.iter().zip(&plane) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn fastscan_tiles_follow_reencode() {
        // compact()-style row rewrites re-encode the planes; the tiles
        // must be re-blocked against the fresh codes, not serve stale ones
        let (n, d, m) = (160usize, 8usize, 4usize);
        let mut rows = random_rows(n, d, 61);
        let mut pv = PqView::train(&rows, d, m, 4, n, 4, 63);
        let mut rng = Pcg64::new(65);
        for x in rows[40 * d..80 * d].iter_mut() {
            *x = rng.gaussian() as f32 * 2.0;
        }
        pv.reencode(&rows);
        let q: Vec<f32> = (0..d).map(|_| rng.gaussian() as f32).collect();
        let luts: Vec<PqLut> = (0..4).map(|_| pv.encode_query(&q)).collect();
        let refs: Vec<&PqLut> = luts.iter().collect();
        let mut fast = vec![0f32; 4 * n];
        pv.scores_batch_fastscan(0, n, &refs, &mut fast);
        let mut plane = vec![0f32; 4 * n];
        pv.scores_batch_plane(0, n, &refs, &mut plane);
        for (a, b) in fast.iter().zip(&plane) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn miri_fastscan_tile_parity_ragged() {
        // Miri-lane subset (scalar kernels pinned by cfg(miri)): the tile
        // re-block + scalar tile kernel vs the plane scalar reference on
        // adversarial shapes — n not a multiple of 32 (ragged tail rows
        // with no tile), odd m, and row ranges whose ends land on every
        // nibble phase around a tile boundary
        for (n, m) in [(67usize, 3usize), (40, 1), (33, 5)] {
            let d = m * 2; // dsub = 2 keeps the Miri run small
            let rows = random_rows(n, d, 71 + n as u64);
            let pv = PqView::train(&rows, d, m, 4, n, 2, 73);
            assert!(pv.fastscan_ready(), "n={n} m={m}");
            let mut rng = Pcg64::new(75);
            let qs: Vec<Vec<f32>> = (0..5)
                .map(|_| (0..d).map(|_| rng.gaussian() as f32).collect())
                .collect();
            let luts: Vec<PqLut> = qs.iter().map(|q| pv.encode_query(q)).collect();
            // 5 queries: one 4-query tile block + one leftover plane query
            let refs: Vec<&PqLut> = luts.iter().collect();
            for (s, e) in [(0usize, n), (1, n - 1), (31, 33.min(n)), (30, n), (32.min(n - 1), n)] {
                let nr = e - s;
                let mut fast = vec![0f32; 5 * nr];
                pv.scores_batch_fastscan(s, e, &refs, &mut fast);
                let mut plane = vec![0f32; 5 * nr];
                pv.scores_batch_plane(s, e, &refs, &mut plane);
                for (a, b) in fast.iter().zip(&plane) {
                    assert_eq!(a.to_bits(), b.to_bits(), "n={n} m={m} range=({s},{e})");
                }
            }
        }
    }

    #[test]
    fn tiny_and_empty_datasets() {
        // n < 2^bits trains fewer centroids; n = 0 must not panic
        let pv = PqView::train(&[], 8, 2, 4, 10, 3, 1);
        assert_eq!(pv.n(), 0);
        let lut = pv.encode_query(&[0.0; 8]);
        assert!(pv.error_bound(&lut) >= 0.0);
        let rows = random_rows(3, 8, 2);
        let pv = PqView::train(&rows, 8, 2, 8, 10, 3, 1);
        assert_eq!(pv.n(), 3);
        let q = vec![1.0f32; 8];
        let lut = pv.encode_query(&q);
        let mut out = vec![0f32; 3];
        pv.scores(0, 3, &lut, &mut out);
        let eps = pv.error_bound(&lut) as f64;
        for r in 0..3 {
            let exact = linalg::dot(&rows[r * 8..(r + 1) * 8], &q) as f64;
            assert!((exact - out[r] as f64).abs() <= eps);
        }
    }
}
