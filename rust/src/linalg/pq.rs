//! Product-quantization (PQ) screening codes — the most compressed tier
//! of the two-stage MIPS scan (Jégou et al. 2011; the screening-tier
//! framing follows Chen et al. 2018, "Learning to Screen for Fast
//! Softmax Inference", but kept **bit-exact** via the same
//! pass-2 + coverage-certificate contract as [`crate::linalg::quant`]).
//!
//! ## Encoding
//!
//! Rows are split into `m` subspaces of `dsub = d/m` dims. Each subspace
//! gets its own k-means codebook of `2^bits` centroids (trained by
//! [`crate::mips::kmeans`] on a deterministic row subsample), and every
//! row stores one code per subspace — `m` bytes/row at 8 bits,
//! `m/2` bytes/row at 4 bits, vs `4d` for f32. Codes are stored
//! **plane-major** (`codes[sub][row]`), so a contiguous scan reads `m`
//! sequential streams and the 4-bit kernels can table-gather 32 rows per
//! instruction.
//!
//! ## Asymmetric-distance scoring
//!
//! A query builds one lookup table per subspace,
//! `lut[sub][c] = q_sub · centroid[sub][c]`, so a row scores as the sum
//! of `m` table entries — no per-row arithmetic beyond the gather. The
//! f64 tables are quantized to **u8 with one shared step** `scale` and
//! per-subspace minima, which makes the hot sum pure integer:
//!
//! ```text
//! score ≈ Q = scale · Σ_sub lut_u8[sub][code] + Σ_sub lmin[sub]
//! ```
//!
//! The integer sum is what the SIMD kernels compute: at 4 bits each
//! subspace table is 16 bytes, so AVX2 `pshufb` / NEON `tbl` gathers 32
//! rows' entries per instruction into u16 lane accumulators (exact for
//! `m ≤ 256`); at 8 bits the gather is an unrolled scalar loop (a
//! 256-entry table exceeds the in-register shuffle width). Every kernel
//! produces the identical integer, and single-/multi-query entry points
//! share the per-row arithmetic, so batch output is bit-identical to
//! per-query calls.
//!
//! ## Error bound / certificate
//!
//! [`PqView::encode_query`] derives the per-query bound the coverage
//! certificate of [`crate::linalg::quant::coverage_proved`] consumes:
//!
//! ```text
//! |score − Q| ≤ Σ_sub ‖q_sub‖₂·maxres_sub   (Cauchy–Schwarz, reconstruction)
//!             + m · scale/2                  (LUT quantization)
//!             + fp slack                     (f32 kernel arithmetic)
//! ```
//!
//! where `maxres_sub` is the largest subspace residual norm over encoded
//! rows. The bound is far looser than SQ8's, so PQ certifies less often
//! — a miss rides the tier ladder (`mips::two_stage`) down to SQ8/f32
//! and correctness never depends on it firing.

use crate::error::Result;
use crate::linalg::simd::{self, Kernel};
use crate::mips::kmeans;
use crate::store::blob::Blob;
use crate::store::format::{tag, ByteWriter, Snapshot, SnapshotWriter};

/// Rows per scoring chunk (keeps the u32 scratch on the stack and the
/// plane segments L1-resident across a batch's queries).
const PQ_CHUNK: usize = 256;

/// Product-quantized shadow copy of a row-major `[n × d]` f32 matrix.
#[derive(Clone, Debug)]
pub struct PqView {
    /// subspaces
    m: usize,
    /// dims per subspace = d/m
    dsub: usize,
    /// codebook slots per subspace = 2^bits (actual count in `csub`)
    k: usize,
    /// bits per code (4 or 8)
    bits: usize,
    n: usize,
    d: usize,
    /// centroids, `[m × k × dsub]` (unused slots zeroed)
    cents: Vec<f32>,
    /// trained centroids per subspace (≤ k; tiny datasets train fewer)
    csub: Vec<usize>,
    /// plane-major codes: bits=8 → `[m × n]`, bits=4 → `[m × ⌈n/2⌉]`
    /// nibble-packed (row r in byte r/2, even rows in the low nibble);
    /// owned or snapshot-mapped
    codes: Blob<u8>,
    /// bytes per plane
    stride: usize,
    /// per-subspace max residual norm `max_r ‖x_sub − cent(code)‖₂`
    maxres: Vec<f32>,
    /// `max |x|` over the encoded matrix (fp-slack ingredient)
    max_abs: f32,
}

/// A query encoded for PQ screening: u8-quantized lookup tables plus the
/// exact offset/scale pair and the precomputed certificate bound.
#[derive(Clone, Debug)]
pub struct PqLut {
    /// u8 table entries, `[m × k]` (shared step, per-subspace minima)
    lut: Vec<u8>,
    /// shared LUT quantization step
    scale: f64,
    /// `Σ_sub lmin[sub]` — the error-free offset part of every score
    off_sum: f64,
    /// per-query error bound (module docs)
    eps: f32,
}

impl PqView {
    /// Train per-subspace codebooks on a deterministic stride-subsample
    /// of ≤ `train_n` rows and encode every row. `m` must divide `d`;
    /// `bits` ∈ {4, 8}. `iters` is clamped to [1, 10] (codebooks of 16
    /// or 256 sub-centroids converge in a handful of Lloyd steps).
    pub fn train(
        rows: &[f32],
        d: usize,
        m: usize,
        bits: usize,
        train_n: usize,
        iters: usize,
        seed: u64,
    ) -> PqView {
        assert!(m >= 1 && d > 0 && d % m == 0, "pq_m must divide d (got m={m}, d={d})");
        assert!(bits == 4 || bits == 8, "pq_bits must be 4 or 8 (got {bits})");
        let n = rows.len() / d;
        debug_assert_eq!(rows.len(), n * d);
        let dsub = d / m;
        let k = 1usize << bits;
        let stride = if bits == 4 { n.div_ceil(2) } else { n };
        let mut pv = PqView {
            m,
            dsub,
            k,
            bits,
            n,
            d,
            cents: vec![0f32; m * k * dsub],
            csub: vec![0usize; m],
            codes: vec![0u8; m * stride].into(),
            stride,
            maxres: vec![0f32; m],
            max_abs: 0.0,
        };
        if n == 0 {
            return pv;
        }
        let tn = train_n.clamp(1, n);
        let step = n.div_ceil(tn);
        let picks: Vec<usize> = (0..n).step_by(step).collect();
        let mut train_buf = vec![0f32; picks.len() * dsub];
        let iters = iters.clamp(1, 10);
        for sub in 0..m {
            let off = sub * dsub;
            for (t, &r) in picks.iter().enumerate() {
                train_buf[t * dsub..(t + 1) * dsub]
                    .copy_from_slice(&rows[r * d + off..r * d + off + dsub]);
            }
            let km = kmeans::train(
                &train_buf,
                picks.len(),
                dsub,
                k.min(picks.len()),
                iters,
                seed ^ ((sub as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15)),
            );
            pv.csub[sub] = km.c;
            pv.cents[sub * k * dsub..sub * k * dsub + km.c * dsub]
                .copy_from_slice(&km.centroids);
        }
        pv.reencode(rows);
        pv
    }

    /// Re-encode every row against the **unchanged** codebooks — the
    /// compaction coherence hook (mirrors re-running the scalar views'
    /// `encode`; codebooks stay fixed like the IVF coarse quantizer).
    /// The nearest-centroid assignment pass is the whole cost of a PQ
    /// (re-)encode — `n·m·2^bits·dsub` distance terms — and each
    /// subspace owns its code plane and `maxres` entry, so the pass fans
    /// out across subspaces on the scoped pool.
    pub fn reencode(&mut self, rows: &[f32]) {
        debug_assert_eq!(rows.len(), self.n * self.d);
        if self.n == 0 {
            return;
        }
        self.max_abs = rows.iter().fold(0f32, |a, &x| a.max(x.abs()));
        let (n, d, m) = (self.n, self.d, self.m);
        let (dsub, k, bits, stride) = (self.dsub, self.k, self.bits, self.stride);
        let cents = &self.cents;
        let csub = &self.csub;
        // threads only pay off once the assignment pass is substantial
        let nthreads = if n * m * k >= (1 << 20) {
            crate::util::pool::default_threads().min(m)
        } else {
            1
        };
        let parts = crate::util::pool::parallel_chunks(m, nthreads, |_, s0, e0| {
            let mut planes = vec![0u8; (e0 - s0) * stride];
            let mut worsts = vec![0f32; e0 - s0];
            for sub in s0..e0 {
                let off = sub * dsub;
                let sc = &cents[sub * k * dsub..(sub + 1) * k * dsub];
                let cs = csub[sub];
                let plane = &mut planes[(sub - s0) * stride..(sub - s0 + 1) * stride];
                let mut worst = 0f64;
                for r in 0..n {
                    let v = &rows[r * d + off..r * d + off + dsub];
                    let (code, d2) = nearest(sc, cs, dsub, v);
                    worst = worst.max(d2);
                    if bits == 8 {
                        plane[r] = code;
                    } else if r % 2 == 0 {
                        plane[r / 2] = (plane[r / 2] & 0xf0) | code;
                    } else {
                        plane[r / 2] = (plane[r / 2] & 0x0f) | (code << 4);
                    }
                }
                worsts[sub - s0] = worst.sqrt() as f32;
            }
            (s0, planes, worsts)
        });
        let codes = self.codes.to_mut();
        for (s0, planes, worsts) in parts {
            let nsub = worsts.len();
            codes[s0 * stride..(s0 + nsub) * stride].copy_from_slice(&planes);
            self.maxres[s0..s0 + nsub].copy_from_slice(&worsts);
        }
    }

    /// Number of encoded rows.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Feature dimension.
    pub fn d(&self) -> usize {
        self.d
    }

    /// Number of subspaces.
    pub fn m(&self) -> usize {
        self.m
    }

    /// Bits per subspace code.
    pub fn bits(&self) -> usize {
        self.bits
    }

    #[inline]
    fn get_code(&self, sub: usize, r: usize) -> u8 {
        if self.bits == 8 {
            self.codes[sub * self.stride + r]
        } else {
            let b = self.codes[sub * self.stride + r / 2];
            if r % 2 == 0 {
                b & 0x0f
            } else {
                b >> 4
            }
        }
    }

    /// Build the per-query lookup tables and certificate bound.
    pub fn encode_query(&self, q: &[f32]) -> PqLut {
        debug_assert_eq!(q.len(), self.d);
        let (m, k, dsub) = (self.m, self.k, self.dsub);
        let mut lutf = vec![0f64; m * k];
        let mut lmin = vec![0f64; m];
        let mut span = 0f64;
        let mut res_term = 0f64;
        let l1: f64 = q.iter().map(|&x| x.abs() as f64).sum();
        for sub in 0..m {
            let qs = &q[sub * dsub..(sub + 1) * dsub];
            let cents = &self.cents[sub * k * dsub..(sub + 1) * k * dsub];
            let cs = self.csub[sub];
            let mut mn = 0f64;
            let mut mx = 0f64;
            for c in 0..cs {
                let cent = &cents[c * dsub..(c + 1) * dsub];
                let mut s = 0f64;
                for (a, b) in qs.iter().zip(cent) {
                    s += *a as f64 * *b as f64;
                }
                lutf[sub * k + c] = s;
                if c == 0 {
                    mn = s;
                    mx = s;
                } else {
                    mn = mn.min(s);
                    mx = mx.max(s);
                }
            }
            lmin[sub] = mn;
            span = span.max(mx - mn);
            let qn: f64 = qs.iter().map(|&a| a as f64 * a as f64).sum();
            res_term += qn.sqrt() * self.maxres[sub] as f64;
        }
        let scale = span / 255.0;
        let inv = if scale > 0.0 { 1.0 / scale } else { 0.0 };
        let mut lut = vec![0u8; m * k];
        let mut off_sum = 0f64;
        for sub in 0..m {
            off_sum += lmin[sub];
            for c in 0..self.csub[sub] {
                lut[sub * k + c] =
                    ((lutf[sub * k + c] - lmin[sub]) * inv).round().clamp(0.0, 255.0) as u8;
            }
        }
        let lut_err = m as f64 * scale * 0.5;
        let fp = (self.d as f64 + 2.0) * 1.2e-7 * self.max_abs as f64 * l1;
        let eps = ((res_term + lut_err + fp) * 1.05 + 1e-12) as f32;
        PqLut { lut, scale, off_sum, eps }
    }

    /// Uniform bound on `|exact score − PQ score|` for every encoded row
    /// against `lut` (derived in [`encode_query`](Self::encode_query)).
    pub fn error_bound(&self, lut: &PqLut) -> f32 {
        lut.eps
    }

    /// PQ approximate scores for rows `[row_start, row_end)`:
    /// `out[i] = Q_{row_start+i}` (module docs).
    pub fn scores(&self, row_start: usize, row_end: usize, lut: &PqLut, out: &mut [f32]) {
        debug_assert!(row_start <= row_end && row_end <= self.n);
        debug_assert_eq!(out.len(), row_end - row_start);
        debug_assert_eq!(lut.lut.len(), self.m * self.k);
        let mut acc = [0u32; PQ_CHUNK];
        let mut r = row_start;
        while r < row_end {
            let e = (r + PQ_CHUNK).min(row_end);
            let nr = e - r;
            self.accum_into(r, e, &lut.lut, &mut acc[..nr]);
            let base = r - row_start;
            for (o, &a) in out[base..base + nr].iter_mut().zip(&acc[..nr]) {
                *o = (lut.scale * a as f64 + lut.off_sum) as f32;
            }
            r = e;
        }
    }

    /// PQ scores for an explicit (gathered) id list — the scattered
    /// candidate-screening form; per-score arithmetic identical to
    /// [`scores`](Self::scores).
    pub fn scores_ids(&self, ids: &[u32], lut: &PqLut, out: &mut [f32]) {
        debug_assert_eq!(out.len(), ids.len());
        for (o, &id) in out.iter_mut().zip(ids) {
            let r = id as usize;
            debug_assert!(r < self.n);
            let mut s = 0u32;
            for sub in 0..self.m {
                s += lut.lut[sub * self.k + self.get_code(sub, r) as usize] as u32;
            }
            *o = (lut.scale * s as f64 + lut.off_sum) as f32;
        }
    }

    /// Multi-query PQ scores — query-major
    /// `out[j·nr + i] = Q_{row_start+i}(luts[j])`. The whole batch works
    /// through each [`PQ_CHUNK`]-row segment of the (tiny) code planes
    /// while it is L1-resident, so codes stream from memory once per
    /// batch. Bit-identical to per-query [`scores`](Self::scores) calls.
    pub fn scores_batch(
        &self,
        row_start: usize,
        row_end: usize,
        luts: &[&PqLut],
        out: &mut [f32],
    ) {
        debug_assert!(row_start <= row_end && row_end <= self.n);
        let nr = row_end - row_start;
        let nq = luts.len();
        debug_assert_eq!(out.len(), nq * nr);
        let mut acc = [0u32; PQ_CHUNK];
        let mut r = row_start;
        while r < row_end {
            let e = (r + PQ_CHUNK).min(row_end);
            let nrr = e - r;
            for (j, lut) in luts.iter().enumerate() {
                self.accum_into(r, e, &lut.lut, &mut acc[..nrr]);
                let base = j * nr + (r - row_start);
                for (o, &a) in out[base..base + nrr].iter_mut().zip(&acc[..nrr]) {
                    *o = (lut.scale * a as f64 + lut.off_sum) as f32;
                }
            }
            r = e;
        }
    }

    /// Integer LUT sums for rows `[row_start, row_end)` into `acc`
    /// (overwritten). Dispatches the 4-bit table-gather kernels when the
    /// u16 lane accumulators cannot overflow (`m ≤ 256`); every kernel
    /// computes the identical integers.
    fn accum_into(&self, row_start: usize, row_end: usize, lut: &[u8], acc: &mut [u32]) {
        debug_assert_eq!(acc.len(), row_end - row_start);
        acc.iter_mut().for_each(|x| *x = 0);
        debug_assert!(row_start <= row_end && row_end <= self.n);
        debug_assert!(lut.len() >= self.m * self.k);
        match simd::kernel() {
            #[cfg(target_arch = "x86_64")]
            // SAFETY: avx2 verified by `simd::detect()`; the guard pins
            // bits == 4 (so each plane holds ⌈n/2⌉ packed bytes and each
            // subspace LUT is k = 16 bytes) and the row range / LUT sizes
            // are debug-asserted above — the kernel's contract.
            Kernel::Avx2 if self.bits == 4 && self.m <= 256 => unsafe {
                self.accum4_avx2(row_start, row_end, lut, acc)
            },
            #[cfg(target_arch = "aarch64")]
            // SAFETY: NEON verified by `simd::detect()`; same bits == 4 /
            // range-containment argument as the AVX2 arm.
            Kernel::Neon if self.bits == 4 && self.m <= 256 => unsafe {
                self.accum4_neon(row_start, row_end, lut, acc)
            },
            _ => self.accum_scalar(row_start, row_end, lut, acc),
        }
    }

    /// Scalar LUT gather (the dispatch fallback, the 8-bit path, and the
    /// test reference). Adds into `acc` over pre-zeroed entries.
    fn accum_scalar(&self, row_start: usize, row_end: usize, lut: &[u8], acc: &mut [u32]) {
        for sub in 0..self.m {
            let l = &lut[sub * self.k..(sub + 1) * self.k];
            let plane = &self.codes[sub * self.stride..(sub + 1) * self.stride];
            if self.bits == 8 {
                for (a, &c) in acc.iter_mut().zip(&plane[row_start..row_end]) {
                    *a += l[c as usize] as u32;
                }
            } else {
                for (i, r) in (row_start..row_end).enumerate() {
                    let b = plane[r / 2];
                    let c = if r % 2 == 0 { b & 0x0f } else { b >> 4 };
                    acc[i] += l[c as usize] as u32;
                }
            }
        }
    }

    /// AVX2 4-bit kernel: per subspace, `pshufb` gathers 32 rows' table
    /// entries from the 16-byte LUT in one shuffle; entries accumulate in
    /// u16 lanes (exact: `m ≤ 256` ⇒ sums ≤ 255·256 < 2¹⁶) and widen to
    /// u32 on store. Scalar prologue/epilogue handle the odd-row nibble
    /// phase and the ragged tail.
    ///
    /// # Safety
    /// Caller must guarantee AVX2 availability (guaranteed via
    /// [`crate::linalg::simd::kernel`]), `self.bits == 4` (so every code
    /// plane holds `stride = ⌈n/2⌉` packed bytes and every subspace LUT
    /// is `k = 16` bytes), `row_start ≤ row_end ≤ self.n`,
    /// `acc.len() == row_end − row_start`, and `lut.len() ≥ m·k`.
    // See `linalg::simd`'s `avx2` module for why `unused_unsafe` is
    // tolerated on the SIMD kernels.
    #[cfg(target_arch = "x86_64")]
    #[allow(unused_unsafe)]
    #[target_feature(enable = "avx2")]
    unsafe fn accum4_avx2(&self, row_start: usize, row_end: usize, lut: &[u8], acc: &mut [u32]) {
        use std::arch::x86_64::*;
        debug_assert!(std::arch::is_x86_feature_detected!("avx2"));
        debug_assert_eq!(self.bits, 4);
        debug_assert!(row_start <= row_end && row_end <= self.n);
        debug_assert_eq!(acc.len(), row_end - row_start);
        debug_assert!(lut.len() >= self.m * self.k);
        let mut r = row_start;
        if r % 2 == 1 && r < row_end {
            self.accum_scalar(r, r + 1, lut, &mut acc[..1]);
            r += 1;
        }
        // SAFETY: value-only constant splat.
        let mask = unsafe { _mm_set1_epi8(0x0f) };
        while r + 32 <= row_end {
            let base = r - row_start;
            // SAFETY: value-only accumulator zeroing.
            let mut a0 = unsafe { _mm256_setzero_si256() }; // rows r..r+16, u16 lanes
            let mut a1 = unsafe { _mm256_setzero_si256() }; // rows r+16..r+32
            for sub in 0..self.m {
                // SAFETY: r is even here and r + 32 ≤ row_end ≤ n, so the
                // 16-byte packed load covers bytes r/2..r/2+16 with
                // r/2 + 15 ≤ (row_end − 32)/2 + 15 < ⌈n/2⌉ = stride,
                // inside plane `sub` of the codes blob (m·stride bytes).
                let raw = unsafe {
                    _mm_loadu_si128(
                        self.codes.as_ptr().add(sub * self.stride + r / 2).cast::<__m128i>(),
                    )
                };
                // SAFETY: the 16-byte LUT load reads lut[sub·k..sub·k+16]
                // with k = 16 and lut.len() ≥ m·k; the shuffle/unpack/
                // widen/add chain is value-only.
                unsafe {
                    let lo = _mm_and_si128(raw, mask);
                    let hi = _mm_and_si128(_mm_srli_epi16::<4>(raw), mask);
                    let tbl =
                        _mm_loadu_si128(lut.as_ptr().add(sub * self.k).cast::<__m128i>());
                    let tlo = _mm_shuffle_epi8(tbl, lo);
                    let thi = _mm_shuffle_epi8(tbl, hi);
                    let even = _mm_unpacklo_epi8(tlo, thi); // rows r..r+16 in order
                    let odd = _mm_unpackhi_epi8(tlo, thi); // rows r+16..r+32
                    a0 = _mm256_add_epi16(a0, _mm256_cvtepu8_epi16(even));
                    a1 = _mm256_add_epi16(a1, _mm256_cvtepu8_epi16(odd));
                }
            }
            // SAFETY: `store_u16_as_u32` writes 16 u32 each at `base` and
            // `base + 16`; the largest index touched is base + 31 =
            // r + 31 − row_start ≤ row_end − 1 − row_start < acc.len().
            unsafe {
                store_u16_as_u32(a0, acc.as_mut_ptr().add(base));
                store_u16_as_u32(a1, acc.as_mut_ptr().add(base + 16));
            }
            r += 32;
        }
        if r < row_end {
            let base = r - row_start;
            self.accum_scalar(r, row_end, lut, &mut acc[base..]);
        }
    }

    /// NEON 4-bit kernel: `tbl` (vqtbl1q) gathers 32 rows' entries per
    /// subspace from the 16-byte LUT; u16 widening accumulate, u32 store.
    ///
    /// # Safety
    /// Same contract as [`accum4_avx2`](Self::accum4_avx2) with NEON in
    /// place of AVX2.
    // See `linalg::simd`'s `avx2` module for why `unused_unsafe` is
    // tolerated on the SIMD kernels.
    #[cfg(target_arch = "aarch64")]
    #[allow(unused_unsafe)]
    #[target_feature(enable = "neon")]
    unsafe fn accum4_neon(&self, row_start: usize, row_end: usize, lut: &[u8], acc: &mut [u32]) {
        use std::arch::aarch64::*;
        debug_assert!(std::arch::is_aarch64_feature_detected!("neon"));
        debug_assert_eq!(self.bits, 4);
        debug_assert!(row_start <= row_end && row_end <= self.n);
        debug_assert_eq!(acc.len(), row_end - row_start);
        debug_assert!(lut.len() >= self.m * self.k);
        let mut r = row_start;
        if r % 2 == 1 && r < row_end {
            self.accum_scalar(r, r + 1, lut, &mut acc[..1]);
            r += 1;
        }
        while r + 32 <= row_end {
            let base = r - row_start;
            // SAFETY: value-only accumulator zeroing.
            let mut a = unsafe { [vdupq_n_u16(0); 4] }; // rows r+0..8, 8..16, 16..24, 24..32
            for sub in 0..self.m {
                // SAFETY: r is even and r + 32 ≤ row_end ≤ n, so the
                // 16-byte packed load covers bytes r/2..r/2+16 with
                // r/2 + 15 < ⌈n/2⌉ = stride inside plane `sub`; the LUT
                // load reads lut[sub·k..sub·k+16] with k = 16 and
                // lut.len() ≥ m·k; the tbl/zip/widening-add chain is
                // value-only.
                unsafe {
                    let raw = vld1q_u8(self.codes.as_ptr().add(sub * self.stride + r / 2));
                    let lo = vandq_u8(raw, vdupq_n_u8(0x0f));
                    let hi = vshrq_n_u8::<4>(raw);
                    let tbl = vld1q_u8(lut.as_ptr().add(sub * self.k));
                    let tlo = vqtbl1q_u8(tbl, lo);
                    let thi = vqtbl1q_u8(tbl, hi);
                    let even = vzip1q_u8(tlo, thi); // rows r..r+16 in order
                    let odd = vzip2q_u8(tlo, thi); // rows r+16..r+32
                    a[0] = vaddw_u8(a[0], vget_low_u8(even));
                    a[1] = vaddw_u8(a[1], vget_high_u8(even));
                    a[2] = vaddw_u8(a[2], vget_low_u8(odd));
                    a[3] = vaddw_u8(a[3], vget_high_u8(odd));
                }
            }
            for (t, &av) in a.iter().enumerate() {
                // SAFETY: the two 4-lane stores per accumulator write
                // acc[base + t·8 .. base + t·8 + 8]; the largest index is
                // base + 31 < acc.len() (see the loop bound r + 32 ≤
                // row_end and acc.len() == row_end − row_start).
                unsafe {
                    vst1q_u32(acc.as_mut_ptr().add(base + t * 8), vmovl_u16(vget_low_u16(av)));
                    vst1q_u32(
                        acc.as_mut_ptr().add(base + t * 8 + 4),
                        vmovl_u16(vget_high_u16(av)),
                    );
                }
            }
            r += 32;
        }
        if r < row_end {
            let base = r - row_start;
            self.accum_scalar(r, row_end, lut, &mut acc[base..]);
        }
    }
}

// ---------------------------------------------------------------------------
// snapshot persistence (crate::store)
// ---------------------------------------------------------------------------

impl PqView {
    /// Write this view as `PQ_META` + `PQ_CODES` sections under `arg`.
    pub(crate) fn save_sections(&self, w: &mut SnapshotWriter, arg: u32) -> Result<()> {
        let mut m = ByteWriter::default();
        m.u64(self.m as u64);
        m.u64(self.dsub as u64);
        m.u64(self.k as u64);
        m.u64(self.bits as u64);
        m.u64(self.n as u64);
        m.u64(self.d as u64);
        m.u64(self.stride as u64);
        m.f32(self.max_abs);
        let csub: Vec<u64> = self.csub.iter().map(|&c| c as u64).collect();
        m.slice(&csub);
        m.slice(&self.maxres);
        m.slice(&self.cents);
        w.section(tag::PQ_META, arg, m.bytes())?;
        w.section(tag::PQ_CODES, arg, &self.codes)
    }

    /// Reopen from a snapshot; the code planes serve zero-copy when the
    /// snapshot is mapped. `None` when the sections are missing, corrupt,
    /// or shape-inconsistent — the tier ladder then degrades.
    pub(crate) fn open_sections(snap: &Snapshot, arg: u32) -> Option<PqView> {
        let mut r = snap.reader_soft(tag::PQ_META, arg)?;
        let m = r.usize().ok()?;
        let dsub = r.usize().ok()?;
        let k = r.usize().ok()?;
        let bits = r.usize().ok()?;
        let n = r.usize().ok()?;
        let d = r.usize().ok()?;
        let stride = r.usize().ok()?;
        let max_abs = r.f32().ok()?;
        let csub64: Vec<u64> = r.vec().ok()?;
        let maxres: Vec<f32> = r.vec().ok()?;
        let cents: Vec<f32> = r.vec().ok()?;
        let codes: Blob<u8> = snap.blob_soft(tag::PQ_CODES, arg)?;
        if !(bits == 4 || bits == 8)
            || m == 0
            || k != 1usize << bits
            || m.checked_mul(dsub)? != d
            || stride != if bits == 4 { n.div_ceil(2) } else { n }
        {
            return None;
        }
        let csub: Vec<usize> = csub64.iter().map(|&c| c as usize).collect();
        if csub.len() != m
            || maxres.len() != m
            || cents.len() != m.checked_mul(k)?.checked_mul(dsub)?
            || codes.len() != m.checked_mul(stride)?
            || csub.iter().any(|&c| c > k)
        {
            return None;
        }
        Some(PqView { m, dsub, k, bits, n, d, cents, csub, codes, stride, maxres, max_abs })
    }
}

/// Widen 16 u16 lanes to u32 and store (AVX2 helper).
///
/// # Safety
/// Caller must guarantee AVX2 availability and that `dst` is valid for
/// 16 u32 writes (`dst..dst + 16`).
// See `linalg::simd`'s `avx2` module for why `unused_unsafe` is
// tolerated on the SIMD kernels.
#[cfg(target_arch = "x86_64")]
#[allow(unused_unsafe)]
#[target_feature(enable = "avx2")]
unsafe fn store_u16_as_u32(v: std::arch::x86_64::__m256i, dst: *mut u32) {
    use std::arch::x86_64::*;
    // SAFETY: lane split/widen are value-only; the two unaligned 8-lane
    // stores cover exactly dst..dst+16, valid per this fn's contract.
    unsafe {
        let lo = _mm256_castsi256_si128(v);
        let hi = _mm256_extracti128_si256::<1>(v);
        _mm256_storeu_si256(dst.cast::<__m256i>(), _mm256_cvtepu16_epi32(lo));
        _mm256_storeu_si256(dst.add(8).cast::<__m256i>(), _mm256_cvtepu16_epi32(hi));
    }
}

/// Nearest centroid among the first `cs` of `cents` (L2), returning
/// `(code, squared distance)` — the assignment step of encoding.
fn nearest(cents: &[f32], cs: usize, dsub: usize, v: &[f32]) -> (u8, f64) {
    let mut best = 0usize;
    let mut bd = f64::INFINITY;
    for c in 0..cs {
        let cent = &cents[c * dsub..(c + 1) * dsub];
        let mut s = 0f64;
        for (x, y) in v.iter().zip(cent) {
            let df = (x - y) as f64;
            s += df * df;
        }
        if s < bd {
            bd = s;
            best = c;
        }
    }
    (best as u8, bd)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg;
    use crate::util::check::Checker;
    use crate::util::rng::Pcg64;

    fn random_rows(n: usize, d: usize, seed: u64) -> Vec<f32> {
        let mut rng = Pcg64::new(seed);
        (0..n * d).map(|_| rng.gaussian() as f32).collect()
    }

    #[test]
    fn property_pq_error_bound_holds_per_row() {
        // the certificate contract: |exact − Q| ≤ ε for EVERY row, across
        // dims, subspace counts, and both code widths
        Checker::new(61).cases(40).check_u64(1u64 << 32, |seed| {
            let mut rng = Pcg64::new(seed ^ 0x90);
            let n = 50 + rng.next_below(300) as usize;
            let dsub = 1 + rng.next_below(6) as usize;
            let m = 1 + rng.next_below(8) as usize;
            let d = m * dsub;
            let rows = random_rows(n, d, seed);
            let q: Vec<f32> = (0..d).map(|_| rng.gaussian() as f32).collect();
            for bits in [4usize, 8] {
                let pv = PqView::train(&rows, d, m, bits, n, 5, seed);
                let lut = pv.encode_query(&q);
                let eps = pv.error_bound(&lut) as f64;
                let mut out = vec![0f32; n];
                pv.scores(0, n, &lut, &mut out);
                for r in 0..n {
                    let exact = linalg::dot(&rows[r * d..(r + 1) * d], &q) as f64;
                    if (exact - out[r] as f64).abs() > eps {
                        return false;
                    }
                }
            }
            true
        });
    }

    #[test]
    fn simd_accum_matches_scalar_on_ragged_ranges() {
        // the 4-bit table-gather kernel vs the scalar reference, across
        // odd starts (nibble phase), 32-row blocks, and ragged tails
        let (n, d, m) = (301usize, 16usize, 8usize);
        let rows = random_rows(n, d, 7);
        let pv = PqView::train(&rows, d, m, 4, n, 4, 9);
        let mut rng = Pcg64::new(11);
        let q: Vec<f32> = (0..d).map(|_| rng.gaussian() as f32).collect();
        let lut = pv.encode_query(&q);
        for (s, e) in [(0usize, 301usize), (1, 300), (3, 36), (0, 31), (32, 96), (299, 301)] {
            let mut got = vec![0u32; e - s];
            pv.accum_into(s, e, &lut.lut, &mut got);
            let mut want = vec![0u32; e - s];
            pv.accum_scalar(s, e, &lut.lut, &mut want);
            assert_eq!(got, want, "range=({s},{e})");
        }
    }

    #[test]
    fn scores_forms_are_bit_identical() {
        // contiguous, scattered, and batched scoring must agree bit for
        // bit on the same rows for both code widths
        let (n, d, m) = (150usize, 12usize, 4usize);
        let rows = random_rows(n, d, 3);
        let mut rng = Pcg64::new(5);
        for bits in [4usize, 8] {
            let pv = PqView::train(&rows, d, m, bits, n, 4, 13);
            let qs: Vec<Vec<f32>> = (0..5)
                .map(|_| (0..d).map(|_| rng.gaussian() as f32).collect())
                .collect();
            let luts: Vec<PqLut> = qs.iter().map(|q| pv.encode_query(q)).collect();
            let refs: Vec<&PqLut> = luts.iter().collect();
            let mut batch = vec![0f32; 5 * n];
            pv.scores_batch(0, n, &refs, &mut batch);
            for (j, lut) in luts.iter().enumerate() {
                let mut single = vec![0f32; n];
                pv.scores(0, n, lut, &mut single);
                for (a, b) in batch[j * n..(j + 1) * n].iter().zip(&single) {
                    assert_eq!(a.to_bits(), b.to_bits(), "bits={bits} q={j}");
                }
                let ids: Vec<u32> = (0..n as u32).rev().collect();
                let mut scattered = vec![0f32; n];
                pv.scores_ids(&ids, lut, &mut scattered);
                for (i, &id) in ids.iter().enumerate() {
                    assert_eq!(
                        scattered[i].to_bits(),
                        single[id as usize].to_bits(),
                        "bits={bits} q={j} id={id}"
                    );
                }
            }
        }
    }

    #[test]
    fn reencode_after_row_change_restores_bound() {
        // rewriting rows and re-encoding must keep the bound sound for
        // the new contents (codebooks unchanged)
        let (n, d, m) = (80usize, 8usize, 4usize);
        let mut rows = random_rows(n, d, 21);
        let mut pv = PqView::train(&rows, d, m, 4, n, 4, 23);
        let mut rng = Pcg64::new(25);
        for x in rows[10 * d..14 * d].iter_mut() {
            *x = 3.0 + rng.gaussian() as f32; // far outside the codebooks
        }
        pv.reencode(&rows);
        let q: Vec<f32> = (0..d).map(|_| rng.gaussian() as f32).collect();
        let lut = pv.encode_query(&q);
        let eps = pv.error_bound(&lut) as f64;
        let mut out = vec![0f32; n];
        pv.scores(0, n, &lut, &mut out);
        for r in 0..n {
            let exact = linalg::dot(&rows[r * d..(r + 1) * d], &q) as f64;
            assert!((exact - out[r] as f64).abs() <= eps, "row {r}");
        }
    }

    #[test]
    fn miri_pq_scalar_accum_and_bound_small() {
        // Miri-lane subset (scalar kernel pinned by cfg(miri)): the 4-bit
        // nibble gather and the certificate bound on a tiny instance
        let (n, d, m) = (37usize, 8usize, 4usize);
        let rows = random_rows(n, d, 31);
        let mut rng = Pcg64::new(33);
        let q: Vec<f32> = (0..d).map(|_| rng.gaussian() as f32).collect();
        for bits in [4usize, 8] {
            let pv = PqView::train(&rows, d, m, bits, n, 2, 35);
            let lut = pv.encode_query(&q);
            let eps = pv.error_bound(&lut) as f64;
            let mut out = vec![0f32; n];
            pv.scores(0, n, &lut, &mut out);
            for r in 0..n {
                let exact = linalg::dot(&rows[r * d..(r + 1) * d], &q) as f64;
                assert!((exact - out[r] as f64).abs() <= eps, "bits={bits} row={r}");
            }
            // odd start exercises the nibble-phase prologue
            let mut a = vec![0u32; 5];
            pv.accum_into(1, 6, &lut.lut, &mut a);
            let mut w = vec![0u32; 5];
            pv.accum_scalar(1, 6, &lut.lut, &mut w);
            assert_eq!(a, w, "bits={bits}");
        }
    }

    #[test]
    fn tiny_and_empty_datasets() {
        // n < 2^bits trains fewer centroids; n = 0 must not panic
        let pv = PqView::train(&[], 8, 2, 4, 10, 3, 1);
        assert_eq!(pv.n(), 0);
        let lut = pv.encode_query(&[0.0; 8]);
        assert!(pv.error_bound(&lut) >= 0.0);
        let rows = random_rows(3, 8, 2);
        let pv = PqView::train(&rows, 8, 2, 8, 10, 3, 1);
        assert_eq!(pv.n(), 3);
        let q = vec![1.0f32; 8];
        let lut = pv.encode_query(&q);
        let mut out = vec![0f32; 3];
        pv.scores(0, 3, &lut, &mut out);
        let eps = pv.error_bound(&lut) as f64;
        for r in 0..3 {
            let exact = linalg::dot(&rows[r * 8..(r + 1) * 8], &q) as f64;
            assert!((exact - out[r] as f64).abs() <= eps);
        }
    }
}
