//! Explicit-SIMD scoring kernels with one-time runtime dispatch.
//!
//! Everything the paper computes reduces to inner products `θ·φ(x_i)` over
//! row blocks plus streaming `(max, Σexp)` reductions, so this module is
//! the floor the whole system's throughput stands on. Design:
//!
//! * **Dispatch once.** [`kernel`] probes the CPU a single time at first
//!   use (`OnceLock`) and every entry point branches on the cached
//!   [`Kernel`] — AVX2+FMA on x86-64 when detected, NEON on aarch64, and
//!   a portable unrolled scalar fallback everywhere else. No per-call
//!   feature probing, no trait objects on the innermost loops.
//!
//! * **One accumulation order.** Every kernel family accumulates each
//!   query with a single vector accumulator (horizontal sum at the end,
//!   scalar tail after), and the multi-query kernels run the *same*
//!   per-query sequence of fused multiply-adds as the single-query ones.
//!   Single-query and batched entry points therefore produce bit-identical
//!   scores, which the batched MIPS paths rely on for id-level parity with
//!   the per-query paths.
//!
//! * **Fused reductions.** [`block_max_sumexp`] and
//!   [`block_expect_fragment`] evaluate scores in L1-resident chunks of
//!   [`CHUNK`] rows and fold them straight into the running
//!   `(max, Σexp(s−max))` (and `Σexp·φ`) state — no full score buffer is
//!   ever materialized and no second pass over memory happens, unlike the
//!   seed's score-then-`push_all` two-pass shape. The exponentials use a
//!   vectorized Cephes-style polynomial `expf` (|rel err| ≲ 2e-7), well
//!   inside the 1e-3 tolerance the estimator tests demand.
//!
//! * **Multi-query batching.** [`matvec_block_multi`] scores one row block
//!   against `nq` queries at once, register-blocking queries in groups so
//!   each database row is streamed from memory exactly once per batch —
//!   the amortization the batched MIPS/estimator/coordinator layers
//!   exploit under concurrent traffic.

use crate::linalg::MaxSumExp;
use std::sync::OnceLock;

/// Rows per fused-reduction chunk: the chunk's scores fit comfortably in
/// L1 while amortizing the running-max rescale across many rows.
const CHUNK: usize = 128;

/// Instruction set selected for this process.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kernel {
    /// Portable unrolled scalar kernels (LLVM autovectorizes these).
    Scalar,
    /// AVX2 + FMA `std::arch` kernels (x86-64).
    Avx2,
    /// NEON `std::arch` kernels (aarch64).
    Neon,
}

impl Kernel {
    /// Short name for logs / bench output.
    pub fn name(self) -> &'static str {
        match self {
            Kernel::Scalar => "scalar",
            Kernel::Avx2 => "avx2+fma",
            Kernel::Neon => "neon",
        }
    }
}

static KERNEL: OnceLock<Kernel> = OnceLock::new();

/// The kernel chosen for this process (detected on first call, cached).
#[inline]
pub fn kernel() -> Kernel {
    *KERNEL.get_or_init(detect)
}

fn detect() -> Kernel {
    if force_scalar() {
        return Kernel::Scalar;
    }
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma") {
            return Kernel::Avx2;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if std::arch::is_aarch64_feature_detected!("neon") {
            return Kernel::Neon;
        }
    }
    Kernel::Scalar
}

/// Forced-scalar seam: `GMIPS_FORCE_SCALAR` set to anything but `0`/empty
/// pins the process to the portable scalar kernels. Every kernel family —
/// the f32 kernels here, SQ8/SQ4 integer scans in [`crate::linalg::quant`]
/// and PQ accumulation in [`crate::linalg::pq`] — dispatches through
/// [`kernel`], so one override covers them all. Under Miri the default
/// flips on (`cfg(miri)`) so the interpreter executes the scalar paths
/// instead of `std::arch` intrinsics it cannot run; an explicit
/// `GMIPS_FORCE_SCALAR=0` still wins over that default. Because the
/// scalar kernels are the bit-level reference the SIMD parity tests
/// compare against, a forced-scalar run is a drop-in replacement, not a
/// semantic variant.
fn force_scalar() -> bool {
    match std::env::var("GMIPS_FORCE_SCALAR") {
        Ok(v) => !(v.is_empty() || v == "0"),
        Err(_) => cfg!(miri),
    }
}

// ---------------------------------------------------------------------------
// public dispatching entry points
// ---------------------------------------------------------------------------

/// Dot product. Bit-identical to one query lane of [`matvec_block_multi`].
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    match kernel() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `detect()` returned Avx2 only after verifying avx2+fma on
        // this CPU, and the kernel reads exactly `min(a.len(), b.len())`
        // lanes from each slice (equal lengths are this fn's contract,
        // debug-asserted above and re-checked inside the kernel).
        Kernel::Avx2 => unsafe { avx2::dot(a, b) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: `detect()` verified NEON; same slice-bounds argument as
        // the AVX2 arm.
        Kernel::Neon => unsafe { neon::dot(a, b) },
        _ => dot_scalar(a, b),
    }
}

/// Scores for a contiguous row block: `out[r] = rows[r·d..]·q`.
pub fn matvec_block(rows: &[f32], d: usize, q: &[f32], out: &mut [f32]) {
    debug_assert_eq!(q.len(), d);
    debug_assert_eq!(rows.len(), out.len() * d);
    if d == 0 {
        out.iter_mut().for_each(|x| *x = 0.0);
        return;
    }
    match kernel() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: avx2+fma verified by `detect()`; the layout contract
        // (`q.len() == d`, `rows.len() == out.len()·d`) is debug-asserted
        // above and re-checked by the kernel's own debug_asserts, and the
        // kernel reads row `r` only at offsets `r·d..r·d+d`.
        Kernel::Avx2 => unsafe { avx2::matvec(rows, d, q, out) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON verified by `detect()`; same layout argument as the
        // AVX2 arm.
        Kernel::Neon => unsafe { neon::matvec(rows, d, q, out) },
        _ => matvec_scalar(rows, d, q, out),
    }
}

/// Multi-query block scoring: `out[j·nrows + r] = rows[r·d..]·qs[j·d..]`
/// (query-major output, `nrows = rows.len()/d`). Each row is read from
/// memory once for the whole batch; per-query results are bit-identical
/// to [`matvec_block`] on the same rows.
pub fn matvec_block_multi(rows: &[f32], d: usize, qs: &[f32], nq: usize, out: &mut [f32]) {
    if d == 0 || nq == 0 {
        out.iter_mut().for_each(|x| *x = 0.0);
        return;
    }
    let nrows = rows.len() / d;
    debug_assert_eq!(rows.len(), nrows * d);
    debug_assert_eq!(qs.len(), nq * d);
    debug_assert_eq!(out.len(), nq * nrows);
    match kernel() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: avx2+fma verified by `detect()`; the batched layout
        // (`qs.len() == nq·d`, `out.len() == nq·nrows`,
        // `rows.len() == nrows·d`) is debug-asserted above and re-checked
        // inside the kernel, which indexes queries and rows only inside
        // those extents.
        Kernel::Avx2 => unsafe { avx2::matvec_multi(rows, d, qs, nq, out) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON verified by `detect()`; same batched-layout
        // argument as the AVX2 arm.
        Kernel::Neon => unsafe { neon::matvec_multi(rows, d, qs, nq, out) },
        _ => {
            for j in 0..nq {
                let q = &qs[j * d..(j + 1) * d];
                matvec_scalar(rows, d, q, &mut out[j * nrows..(j + 1) * nrows]);
            }
        }
    }
}

/// `y += alpha·x`.
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    match kernel() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: avx2+fma verified by `detect()`; the kernel reads/writes
        // only `min(x.len(), y.len())` lanes (equal lengths debug-asserted
        // above and inside the kernel).
        Kernel::Avx2 => unsafe { avx2::axpy(alpha, x, y) },
        _ => {
            for (yi, xi) in y.iter_mut().zip(x) {
                *yi += alpha * xi;
            }
        }
    }
}

/// Fused single-pass partition fragment over a row block: scores are
/// produced chunk-at-a-time and folded straight into the running
/// `(max, Σexp(s − max))` state — the seed's two-pass
/// score-buffer-then-`push_all` shape never touches memory twice here.
pub fn block_max_sumexp(rows: &[f32], d: usize, q: &[f32]) -> MaxSumExp {
    debug_assert_eq!(q.len(), d);
    let n = if d == 0 { 0 } else { rows.len() / d };
    debug_assert_eq!(rows.len(), n * d);
    let mut acc = MaxSumExp::default();
    let mut buf = [0f32; CHUNK];
    let mut start = 0;
    while start < n {
        let end = (start + CHUNK).min(n);
        let chunk = &mut buf[..end - start];
        matvec_block(&rows[start * d..end * d], d, q, chunk);
        let cmax = max_slice(chunk) as f64;
        if cmax > acc.max {
            // rescale the running sum to the new reference point; exp(-inf)
            // = 0 makes the first chunk initialize cleanly
            acc.sumexp *= (acc.max - cmax).exp();
            acc.max = cmax;
        }
        acc.sumexp += sum_exp_sub(chunk, acc.max as f32) as f64;
        acc.count += (end - start) as u64;
        start = end;
    }
    acc
}

/// Fused single-pass expectation fragment: the partition fragment of
/// [`block_max_sumexp`] plus the weighted feature sum
/// `wsum = Σ_r exp(s_r − max)·rows[r]`, with the running `wsum` rescaled
/// whenever a chunk raises the reference max.
pub fn block_expect_fragment(rows: &[f32], d: usize, q: &[f32]) -> (MaxSumExp, Vec<f32>) {
    debug_assert_eq!(q.len(), d);
    let n = if d == 0 { 0 } else { rows.len() / d };
    debug_assert_eq!(rows.len(), n * d);
    let mut acc = MaxSumExp::default();
    let mut wsum = vec![0f32; d];
    let mut sbuf = [0f32; CHUNK];
    let mut wbuf = [0f32; CHUNK];
    let mut start = 0;
    while start < n {
        let end = (start + CHUNK).min(n);
        let m = end - start;
        let scores = &mut sbuf[..m];
        matvec_block(&rows[start * d..end * d], d, q, scores);
        let cmax = max_slice(scores) as f64;
        if cmax > acc.max {
            let rescale = (acc.max - cmax).exp();
            acc.sumexp *= rescale;
            let r32 = rescale as f32;
            for w in wsum.iter_mut() {
                *w *= r32;
            }
            acc.max = cmax;
        }
        let weights = &mut wbuf[..m];
        exp_sub_into(scores, acc.max as f32, weights);
        let mut csum = 0f64;
        for (r, &w) in weights.iter().enumerate() {
            csum += w as f64;
            axpy(w, &rows[(start + r) * d..(start + r + 1) * d], &mut wsum);
        }
        acc.sumexp += csum;
        acc.count += m as u64;
        start = end;
    }
    (acc, wsum)
}

// ---------------------------------------------------------------------------
// portable scalar kernels (also the reference implementations for tests)
// ---------------------------------------------------------------------------

/// Unrolled scalar dot with 4 independent accumulators (breaks the
/// dependency chain; LLVM autovectorizes it). This is the seed kernel,
/// kept as the dispatch fallback and the test/bench reference.
pub fn dot_scalar(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 8;
    let (mut s0, mut s1, mut s2, mut s3) = (0f32, 0f32, 0f32, 0f32);
    for c in 0..chunks {
        let i = c * 8;
        // SAFETY: the largest index touched below is i + 7, and
        // i + 7 <= (chunks - 1)·8 + 7 = chunks·8 − 1 < n, so all eight
        // offsets i..=i+7 are in bounds for both slices (equal lengths
        // asserted above).
        unsafe {
            s0 += a.get_unchecked(i) * b.get_unchecked(i)
                + a.get_unchecked(i + 4) * b.get_unchecked(i + 4);
            s1 += a.get_unchecked(i + 1) * b.get_unchecked(i + 1)
                + a.get_unchecked(i + 5) * b.get_unchecked(i + 5);
            s2 += a.get_unchecked(i + 2) * b.get_unchecked(i + 2)
                + a.get_unchecked(i + 6) * b.get_unchecked(i + 6);
            s3 += a.get_unchecked(i + 3) * b.get_unchecked(i + 3)
                + a.get_unchecked(i + 7) * b.get_unchecked(i + 7);
        }
    }
    let mut tail = 0f32;
    for i in chunks * 8..n {
        tail += a[i] * b[i];
    }
    s0 + s1 + s2 + s3 + tail
}

fn matvec_scalar(rows: &[f32], d: usize, q: &[f32], out: &mut [f32]) {
    for (r, o) in out.iter_mut().enumerate() {
        *o = dot_scalar(&rows[r * d..(r + 1) * d], q);
    }
}

fn max_slice(xs: &[f32]) -> f32 {
    debug_assert!(!xs.is_empty());
    match kernel() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: avx2+fma verified by `detect()`; the kernel reads only
        // within `xs` (vector body over `len/8` chunks, scalar tail).
        Kernel::Avx2 => unsafe { avx2::max_slice(xs) },
        _ => xs.iter().cloned().fold(f32::NEG_INFINITY, f32::max),
    }
}

fn sum_exp_sub(xs: &[f32], m: f32) -> f32 {
    match kernel() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: avx2+fma verified by `detect()`; the kernel reads only
        // within `xs` and the exp polynomial is value-only arithmetic.
        Kernel::Avx2 => unsafe { avx2::sum_exp_sub(xs, m) },
        _ => xs.iter().map(|&x| exp_f32(x - m)).sum(),
    }
}

fn exp_sub_into(xs: &[f32], m: f32, out: &mut [f32]) {
    debug_assert_eq!(xs.len(), out.len());
    match kernel() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: avx2+fma verified by `detect()`; the kernel reads
        // `min(xs.len(), out.len())` lanes from `xs` and writes the same
        // extent of `out` (equal lengths debug-asserted above and inside
        // the kernel).
        Kernel::Avx2 => unsafe { avx2::exp_sub_into(xs, m, out) },
        _ => {
            for (o, &x) in out.iter_mut().zip(xs) {
                *o = exp_f32(x - m);
            }
        }
    }
}

/// Cephes-style polynomial `expf` (|rel err| ≲ 2e-7 over the clamped
/// range). Shared by the scalar fused path and the SIMD tails so every
/// lane has the same accuracy profile. Inputs here are always ≤ 0
/// (scores minus a running max), so the upper clamp never binds.
#[inline]
pub(crate) fn exp_f32(x: f32) -> f32 {
    const C1: f32 = 0.693_359_375; // ln 2, Cody–Waite high part
    const C2: f32 = -2.121_944_4e-4; // ln 2, Cody–Waite low part
    // upper clamp 87.0 keeps fx ≤ 126 so the exponent-bit scaling below
    // can never overflow to Inf (exp(87) ≈ 6e37 < f32::MAX)
    let x = x.clamp(-87.336_54, 87.0);
    let fx = (x * std::f32::consts::LOG2_E + 0.5).floor();
    let x = x - fx * C1 - fx * C2;
    let z = x * x;
    let mut y = 1.987_569_2e-4;
    y = y * x + 1.398_199_9e-3;
    y = y * x + 8.333_452e-3;
    y = y * x + 4.166_579_6e-2;
    y = y * x + 1.666_666_5e-1;
    y = y * x + 5.000_000_3e-1;
    y = y * z + x + 1.0;
    // scale by 2^fx through the exponent bits (fx ∈ [-126, 126] after the
    // clamp, so the biased exponent stays strictly inside the finite range)
    let bits = (((fx as i32) + 127) << 23) as u32;
    y * f32::from_bits(bits)
}

// ---------------------------------------------------------------------------
// AVX2 + FMA kernels (x86-64)
// ---------------------------------------------------------------------------

// `unused_unsafe` tolerated inside the arch modules only: the value-only
// `std::arch` intrinsics (no pointer operands) flipped from `unsafe fn` to
// safe-in-`#[target_feature]` in Rust 1.87, so the explicit `unsafe { .. }`
// blocks below — required by `deny(unsafe_op_in_unsafe_fn)` on pre-1.87
// toolchains — become redundant (but still correct) on newer ones. Every
// block still carries its SAFETY justification; `cargo xtask lint`
// enforces that.
#[cfg(target_arch = "x86_64")]
#[allow(unused_unsafe)]
mod avx2 {
    use std::arch::x86_64::*;

    /// True iff this CPU really has the features these kernels are compiled
    /// for — the dispatcher's invariant, re-checked (debug only) at every
    /// kernel entry.
    fn feature_ok() -> bool {
        is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma")
    }

    /// Horizontal sum of the 8 lanes. Value-only intrinsics.
    #[inline]
    #[target_feature(enable = "avx2,fma")]
    unsafe fn hsum(v: __m256) -> f32 {
        // SAFETY: value-only shuffles/adds on register operands — no memory
        // access; avx2+fma is enabled on this fn and holds for the process
        // per the dispatcher's `detect()`.
        unsafe {
            let lo = _mm256_castps256_ps128(v);
            let hi = _mm256_extractf128_ps::<1>(v);
            let s = _mm_add_ps(lo, hi);
            let s = _mm_add_ps(s, _mm_movehl_ps(s, s));
            let s = _mm_add_ss(s, _mm_movehdup_ps(s));
            _mm_cvtss_f32(s)
        }
    }

    /// Horizontal max of the 8 lanes. Value-only intrinsics.
    #[inline]
    #[target_feature(enable = "avx2,fma")]
    unsafe fn hmax(v: __m256) -> f32 {
        // SAFETY: value-only shuffles/maxes on register operands — no
        // memory access; avx2+fma enabled on this fn.
        unsafe {
            let lo = _mm256_castps256_ps128(v);
            let hi = _mm256_extractf128_ps::<1>(v);
            let m = _mm_max_ps(lo, hi);
            let m = _mm_max_ps(m, _mm_movehl_ps(m, m));
            let m = _mm_max_ss(m, _mm_movehdup_ps(m));
            _mm_cvtss_f32(m)
        }
    }

    /// Raw dot kernel. Contract: `a` and `b` are valid for reads of `n`
    /// f32s each, and avx2+fma is available (callers come through the
    /// dispatcher).
    #[target_feature(enable = "avx2,fma")]
    unsafe fn dot_raw(a: *const f32, b: *const f32, n: usize) -> f32 {
        debug_assert!(feature_ok());
        let chunks = n / 8;
        // SAFETY: value-only zeroing of a register accumulator.
        let mut acc = unsafe { _mm256_setzero_ps() };
        for c in 0..chunks {
            let i = c * 8;
            // SAFETY: the highest lane touched is i + 7 ≤ chunks·8 − 1 < n,
            // so both unaligned 8-lane loads are inside the `n`-element
            // buffers the contract promises.
            acc = unsafe {
                _mm256_fmadd_ps(_mm256_loadu_ps(a.add(i)), _mm256_loadu_ps(b.add(i)), acc)
            };
        }
        // SAFETY: `hsum` is value-only; avx2+fma enabled on this fn.
        let mut s = unsafe { hsum(acc) };
        for i in chunks * 8..n {
            // SAFETY: scalar tail, i < n — in bounds for both buffers.
            s += unsafe { *a.add(i) * *b.add(i) };
        }
        s
    }

    /// # Safety
    /// Caller must guarantee `a.len() == b.len()` and that avx2+fma are
    /// available (guaranteed when reached through [`super::kernel`]).
    #[target_feature(enable = "avx2,fma")]
    pub(super) unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len().min(b.len());
        // SAFETY: both pointers come from live slices covering ≥ n
        // elements (n is the min of the two lengths), satisfying
        // `dot_raw`'s read contract; feature availability is this fn's
        // own contract.
        unsafe { dot_raw(a.as_ptr(), b.as_ptr(), n) }
    }

    /// # Safety
    /// Caller must guarantee `q.len() == d`, `rows.len() == out.len()·d`,
    /// and avx2+fma availability (guaranteed via [`super::kernel`]).
    #[target_feature(enable = "avx2,fma")]
    pub(super) unsafe fn matvec(rows: &[f32], d: usize, q: &[f32], out: &mut [f32]) {
        debug_assert_eq!(q.len(), d);
        debug_assert_eq!(rows.len(), out.len() * d);
        for (r, o) in out.iter_mut().enumerate() {
            // SAFETY: row r occupies rows[r·d .. r·d+d] — in bounds because
            // rows.len() == out.len()·d and r < out.len(); q covers d
            // elements by contract. Both satisfy `dot_raw`'s read extents.
            *o = unsafe { dot_raw(rows.as_ptr().add(r * d), q.as_ptr(), d) };
        }
    }

    /// Query-blocked multi-query scoring: 4 query accumulators share each
    /// row load, so a batch streams the row block from memory once. The
    /// per-query FMA sequence matches `dot_raw` exactly (bit-identical
    /// scores to the single-query path).
    ///
    /// # Safety
    /// Caller must guarantee `qs.len() == nq·d`, `out.len() == nq·nrows`
    /// with `nrows = rows.len()/d` and `d | rows.len()`, and avx2+fma
    /// availability (guaranteed via [`super::kernel`]).
    #[target_feature(enable = "avx2,fma")]
    pub(super) unsafe fn matvec_multi(
        rows: &[f32],
        d: usize,
        qs: &[f32],
        nq: usize,
        out: &mut [f32],
    ) {
        debug_assert!(feature_ok());
        let nrows = rows.len() / d;
        debug_assert_eq!(rows.len(), nrows * d);
        debug_assert_eq!(qs.len(), nq * d);
        debug_assert_eq!(out.len(), nq * nrows);
        let chunks = d / 8;
        let mut j = 0;
        while j + 4 <= nq {
            // SAFETY: queries j..j+3 satisfy (j+3)·d + d ≤ nq·d == qs.len(),
            // so each base pointer heads a full d-element query lane.
            let (q0, q1, q2, q3) = unsafe {
                (
                    qs.as_ptr().add(j * d),
                    qs.as_ptr().add((j + 1) * d),
                    qs.as_ptr().add((j + 2) * d),
                    qs.as_ptr().add((j + 3) * d),
                )
            };
            for r in 0..nrows {
                // SAFETY: r < nrows so row r spans rows[r·d .. r·d+d],
                // inside the slice.
                let row = unsafe { rows.as_ptr().add(r * d) };
                // SAFETY: value-only accumulator zeroing.
                let (mut a0, mut a1, mut a2, mut a3) = unsafe {
                    (
                        _mm256_setzero_ps(),
                        _mm256_setzero_ps(),
                        _mm256_setzero_ps(),
                        _mm256_setzero_ps(),
                    )
                };
                for c in 0..chunks {
                    let i = c * 8;
                    // SAFETY: i + 7 < chunks·8 ≤ d, so the 8-lane loads stay
                    // inside the d-element row and query lanes established
                    // above; FMA itself is value-only.
                    unsafe {
                        let rv = _mm256_loadu_ps(row.add(i));
                        a0 = _mm256_fmadd_ps(rv, _mm256_loadu_ps(q0.add(i)), a0);
                        a1 = _mm256_fmadd_ps(rv, _mm256_loadu_ps(q1.add(i)), a1);
                        a2 = _mm256_fmadd_ps(rv, _mm256_loadu_ps(q2.add(i)), a2);
                        a3 = _mm256_fmadd_ps(rv, _mm256_loadu_ps(q3.add(i)), a3);
                    }
                }
                // SAFETY: `hsum` is value-only; avx2+fma enabled here.
                let (mut s0, mut s1, mut s2, mut s3) =
                    unsafe { (hsum(a0), hsum(a1), hsum(a2), hsum(a3)) };
                for i in chunks * 8..d {
                    // SAFETY: scalar tail, i < d — inside the same row and
                    // query lanes as the vector body.
                    unsafe {
                        let x = *row.add(i);
                        s0 += x * *q0.add(i);
                        s1 += x * *q1.add(i);
                        s2 += x * *q2.add(i);
                        s3 += x * *q3.add(i);
                    }
                }
                out[j * nrows + r] = s0;
                out[(j + 1) * nrows + r] = s1;
                out[(j + 2) * nrows + r] = s2;
                out[(j + 3) * nrows + r] = s3;
            }
            j += 4;
        }
        while j < nq {
            // SAFETY: the per-query remainder reuses `matvec` on in-bounds
            // subslices (j < nq), under this fn's own feature contract.
            unsafe {
                matvec(rows, d, &qs[j * d..(j + 1) * d], &mut out[j * nrows..(j + 1) * nrows]);
            }
            j += 1;
        }
    }

    /// # Safety
    /// Caller must guarantee `x.len() == y.len()` and avx2+fma
    /// availability (guaranteed via [`super::kernel`]).
    #[target_feature(enable = "avx2,fma")]
    pub(super) unsafe fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
        debug_assert!(feature_ok());
        debug_assert_eq!(x.len(), y.len());
        let n = x.len().min(y.len());
        let chunks = n / 8;
        // SAFETY: value-only broadcast.
        let va = unsafe { _mm256_set1_ps(alpha) };
        for c in 0..chunks {
            let i = c * 8;
            // SAFETY: i + 7 < chunks·8 ≤ n ≤ both lengths, so the loads and
            // the store stay inside `x`/`y`; `y`'s store never overlaps the
            // `x` load (distinct slices by &/&mut aliasing rules).
            unsafe {
                let yv = _mm256_loadu_ps(y.as_ptr().add(i));
                let r = _mm256_fmadd_ps(va, _mm256_loadu_ps(x.as_ptr().add(i)), yv);
                _mm256_storeu_ps(y.as_mut_ptr().add(i), r);
            }
        }
        for i in chunks * 8..n {
            y[i] += alpha * x[i];
        }
    }

    /// # Safety
    /// Caller must guarantee avx2+fma availability (guaranteed via
    /// [`super::kernel`]); any slice length is handled.
    #[target_feature(enable = "avx2,fma")]
    pub(super) unsafe fn max_slice(xs: &[f32]) -> f32 {
        debug_assert!(feature_ok());
        let n = xs.len();
        let chunks = n / 8;
        let mut s = f32::NEG_INFINITY;
        if chunks > 0 {
            // SAFETY: chunks ≥ 1 means n ≥ 8, so the head load and every
            // load at c·8 (c < chunks, c·8 + 7 < n) are in bounds; `hmax`
            // is value-only.
            unsafe {
                let mut m = _mm256_loadu_ps(xs.as_ptr());
                for c in 1..chunks {
                    m = _mm256_max_ps(m, _mm256_loadu_ps(xs.as_ptr().add(c * 8)));
                }
                s = hmax(m);
            }
        }
        for i in chunks * 8..n {
            s = s.max(xs[i]);
        }
        s
    }

    /// 8-lane Cephes-style expf (same coefficients as the portable
    /// `exp_f32`, |rel err| ≲ 2e-7 on the clamped range).
    #[target_feature(enable = "avx2,fma")]
    unsafe fn exp256(x: __m256) -> __m256 {
        // SAFETY: the whole polynomial is value-only register arithmetic —
        // no memory access anywhere; avx2+fma enabled on this fn. The
        // upper clamp 87.0 keeps fx ≤ 126 so the exponent-bit scaling
        // cannot overflow to Inf (see the scalar `exp_f32`).
        unsafe {
            let hi = _mm256_set1_ps(87.0);
            let lo = _mm256_set1_ps(-87.336_54);
            let log2e = _mm256_set1_ps(std::f32::consts::LOG2_E);
            let c1 = _mm256_set1_ps(0.693_359_375);
            let c2 = _mm256_set1_ps(-2.121_944_4e-4);
            let one = _mm256_set1_ps(1.0);
            let half = _mm256_set1_ps(0.5);

            let x = _mm256_min_ps(_mm256_max_ps(x, lo), hi);
            let fx = _mm256_floor_ps(_mm256_fmadd_ps(x, log2e, half));
            let x = _mm256_fnmadd_ps(fx, c1, x);
            let x = _mm256_fnmadd_ps(fx, c2, x);
            let z = _mm256_mul_ps(x, x);
            let mut y = _mm256_set1_ps(1.987_569_2e-4);
            y = _mm256_fmadd_ps(y, x, _mm256_set1_ps(1.398_199_9e-3));
            y = _mm256_fmadd_ps(y, x, _mm256_set1_ps(8.333_452e-3));
            y = _mm256_fmadd_ps(y, x, _mm256_set1_ps(4.166_579_6e-2));
            y = _mm256_fmadd_ps(y, x, _mm256_set1_ps(1.666_666_5e-1));
            y = _mm256_fmadd_ps(y, x, _mm256_set1_ps(5.000_000_3e-1));
            y = _mm256_fmadd_ps(y, z, x);
            y = _mm256_add_ps(y, one);
            let n = _mm256_cvtps_epi32(fx);
            let n = _mm256_add_epi32(n, _mm256_set1_epi32(127));
            let n = _mm256_slli_epi32::<23>(n);
            _mm256_mul_ps(y, _mm256_castsi256_ps(n))
        }
    }

    /// # Safety
    /// Caller must guarantee avx2+fma availability (guaranteed via
    /// [`super::kernel`]); any slice length is handled.
    #[target_feature(enable = "avx2,fma")]
    pub(super) unsafe fn sum_exp_sub(xs: &[f32], m: f32) -> f32 {
        debug_assert!(feature_ok());
        let n = xs.len();
        let chunks = n / 8;
        // SAFETY: value-only broadcast and accumulator zeroing.
        let (vm, mut acc) = unsafe { (_mm256_set1_ps(m), _mm256_setzero_ps()) };
        for c in 0..chunks {
            // SAFETY: c·8 + 7 < chunks·8 ≤ n keeps the load inside `xs`;
            // `exp256` and the adds are value-only.
            unsafe {
                let v = _mm256_loadu_ps(xs.as_ptr().add(c * 8));
                acc = _mm256_add_ps(acc, exp256(_mm256_sub_ps(v, vm)));
            }
        }
        // SAFETY: `hsum` is value-only; avx2+fma enabled here.
        let mut s = unsafe { hsum(acc) };
        for i in chunks * 8..n {
            s += super::exp_f32(xs[i] - m);
        }
        s
    }

    /// # Safety
    /// Caller must guarantee `xs.len() == out.len()` and avx2+fma
    /// availability (guaranteed via [`super::kernel`]).
    #[target_feature(enable = "avx2,fma")]
    pub(super) unsafe fn exp_sub_into(xs: &[f32], m: f32, out: &mut [f32]) {
        debug_assert!(feature_ok());
        debug_assert_eq!(xs.len(), out.len());
        let n = xs.len().min(out.len());
        let chunks = n / 8;
        // SAFETY: value-only broadcast.
        let vm = unsafe { _mm256_set1_ps(m) };
        for c in 0..chunks {
            let i = c * 8;
            // SAFETY: i + 7 < chunks·8 ≤ n ≤ both lengths, so the load from
            // `xs` and the store into `out` are in bounds; the two slices
            // cannot alias (& vs &mut).
            unsafe {
                let v = exp256(_mm256_sub_ps(_mm256_loadu_ps(xs.as_ptr().add(i)), vm));
                _mm256_storeu_ps(out.as_mut_ptr().add(i), v);
            }
        }
        for i in chunks * 8..n {
            out[i] = super::exp_f32(xs[i] - m);
        }
    }
}

// ---------------------------------------------------------------------------
// NEON kernels (aarch64) — dot/matvec only; the fused reductions fall back
// to the portable exp path (see the `_` dispatch arms above)
// ---------------------------------------------------------------------------

// See the `avx2` module for why `unused_unsafe` is tolerated here: the
// explicit blocks are required pre-1.87 (`deny(unsafe_op_in_unsafe_fn)`)
// and redundant-but-correct once value-only intrinsics became safe inside
// `#[target_feature]` fns.
#[cfg(target_arch = "aarch64")]
#[allow(unused_unsafe)]
mod neon {
    use std::arch::aarch64::*;

    /// Dispatcher invariant, re-checked (debug only) at kernel entries.
    fn feature_ok() -> bool {
        std::arch::is_aarch64_feature_detected!("neon")
    }

    /// Raw dot kernel. Contract: `a` and `b` are valid for reads of `n`
    /// f32s each, and NEON is available (callers come through the
    /// dispatcher).
    #[target_feature(enable = "neon")]
    unsafe fn dot_raw(a: *const f32, b: *const f32, n: usize) -> f32 {
        debug_assert!(feature_ok());
        let chunks = n / 4;
        // SAFETY: value-only accumulator zeroing.
        let mut acc = unsafe { vdupq_n_f32(0.0) };
        for c in 0..chunks {
            let i = c * 4;
            // SAFETY: the highest lane touched is i + 3 ≤ chunks·4 − 1 < n,
            // so both 4-lane loads are inside the `n`-element buffers the
            // contract promises; the FMA is value-only.
            acc = unsafe { vfmaq_f32(acc, vld1q_f32(a.add(i)), vld1q_f32(b.add(i))) };
        }
        // SAFETY: value-only horizontal reduction.
        let mut s = unsafe { vaddvq_f32(acc) };
        for i in chunks * 4..n {
            // SAFETY: scalar tail, i < n — in bounds for both buffers.
            s += unsafe { *a.add(i) * *b.add(i) };
        }
        s
    }

    /// # Safety
    /// Caller must guarantee `a.len() == b.len()` and NEON availability
    /// (guaranteed when reached through [`super::kernel`]).
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len().min(b.len());
        // SAFETY: both pointers come from live slices covering ≥ n
        // elements, satisfying `dot_raw`'s read contract.
        unsafe { dot_raw(a.as_ptr(), b.as_ptr(), n) }
    }

    /// # Safety
    /// Caller must guarantee `q.len() == d`, `rows.len() == out.len()·d`,
    /// and NEON availability (guaranteed via [`super::kernel`]).
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn matvec(rows: &[f32], d: usize, q: &[f32], out: &mut [f32]) {
        debug_assert_eq!(q.len(), d);
        debug_assert_eq!(rows.len(), out.len() * d);
        for (r, o) in out.iter_mut().enumerate() {
            // SAFETY: row r occupies rows[r·d .. r·d+d] — in bounds because
            // rows.len() == out.len()·d and r < out.len(); q covers d
            // elements by contract.
            *o = unsafe { dot_raw(rows.as_ptr().add(r * d), q.as_ptr(), d) };
        }
    }

    /// 2-query blocking: each row load feeds both query accumulators; the
    /// per-query FMA sequence matches `dot_raw` (bit-identical scores).
    ///
    /// # Safety
    /// Caller must guarantee `qs.len() == nq·d`, `out.len() == nq·nrows`
    /// with `nrows = rows.len()/d` and `d | rows.len()`, and NEON
    /// availability (guaranteed via [`super::kernel`]).
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn matvec_multi(
        rows: &[f32],
        d: usize,
        qs: &[f32],
        nq: usize,
        out: &mut [f32],
    ) {
        debug_assert!(feature_ok());
        let nrows = rows.len() / d;
        debug_assert_eq!(rows.len(), nrows * d);
        debug_assert_eq!(qs.len(), nq * d);
        debug_assert_eq!(out.len(), nq * nrows);
        let chunks = d / 4;
        let mut j = 0;
        while j + 2 <= nq {
            // SAFETY: queries j and j+1 satisfy (j+1)·d + d ≤ nq·d ==
            // qs.len(), so each base pointer heads a full d-element lane.
            let (q0, q1) = unsafe { (qs.as_ptr().add(j * d), qs.as_ptr().add((j + 1) * d)) };
            for r in 0..nrows {
                // SAFETY: r < nrows so row r spans rows[r·d .. r·d+d],
                // inside the slice.
                let row = unsafe { rows.as_ptr().add(r * d) };
                // SAFETY: value-only accumulator zeroing.
                let (mut a0, mut a1) = unsafe { (vdupq_n_f32(0.0), vdupq_n_f32(0.0)) };
                for c in 0..chunks {
                    let i = c * 4;
                    // SAFETY: i + 3 < chunks·4 ≤ d keeps the 4-lane loads
                    // inside the d-element row and query lanes; FMA is
                    // value-only.
                    unsafe {
                        let rv = vld1q_f32(row.add(i));
                        a0 = vfmaq_f32(a0, rv, vld1q_f32(q0.add(i)));
                        a1 = vfmaq_f32(a1, rv, vld1q_f32(q1.add(i)));
                    }
                }
                // SAFETY: value-only horizontal reductions.
                let (mut s0, mut s1) = unsafe { (vaddvq_f32(a0), vaddvq_f32(a1)) };
                for i in chunks * 4..d {
                    // SAFETY: scalar tail, i < d — inside the same row and
                    // query lanes as the vector body.
                    unsafe {
                        let x = *row.add(i);
                        s0 += x * *q0.add(i);
                        s1 += x * *q1.add(i);
                    }
                }
                out[j * nrows + r] = s0;
                out[(j + 1) * nrows + r] = s1;
            }
            j += 2;
        }
        while j < nq {
            // SAFETY: the per-query remainder reuses `matvec` on in-bounds
            // subslices (j < nq), under this fn's own feature contract.
            unsafe {
                matvec(rows, d, &qs[j * d..(j + 1) * d], &mut out[j * nrows..(j + 1) * nrows]);
            }
            j += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::Checker;
    use crate::util::rng::Pcg64;

    fn naive_dot_f64(a: &[f32], b: &[f32]) -> f64 {
        a.iter().zip(b).map(|(&x, &y)| x as f64 * y as f64).sum()
    }

    /// Scalar-reference fused reduction: score with `dot_scalar`, then the
    /// exact-f64 `push_all` — the seed's two-pass semantics.
    fn reference_max_sumexp(rows: &[f32], d: usize, q: &[f32]) -> MaxSumExp {
        let n = rows.len() / d;
        let mut acc = MaxSumExp::default();
        for r in 0..n {
            acc.push(dot_scalar(&rows[r * d..(r + 1) * d], q) as f64);
        }
        acc
    }

    #[test]
    fn kernel_detected_once_and_named() {
        let k = kernel();
        assert_eq!(k, kernel(), "dispatch must be stable");
        assert!(!k.name().is_empty());
    }

    #[test]
    fn forced_scalar_env_pins_dispatch() {
        // `kernel()` caches on first use, so this test can only assert the
        // direction that holds for the current process environment: when
        // the seam is active (env var set, or running under Miri where the
        // default flips on), dispatch must be Scalar. The CI forced-scalar
        // lane runs the whole suite with GMIPS_FORCE_SCALAR=1, which makes
        // every SIMD-vs-scalar parity test above exercise scalar==scalar
        // (bit-identical by construction) and proves the seam is a drop-in.
        if force_scalar() {
            assert_eq!(kernel(), Kernel::Scalar);
        }
        // And the seam's parser: explicit "0"/empty must not force scalar.
        assert!(!matches!(std::env::var("GMIPS_FORCE_SCALAR").as_deref(), Ok("0")) || !force_scalar());
    }

    /// Miri-sized kernel subset: under Miri the seam pins dispatch to the
    /// scalar kernels, so this exercises `dot_scalar`'s unchecked indexing,
    /// the fused reductions' chunk loop, and `exp_f32`'s bit manipulation
    /// on sizes an interpreter can afford.
    #[test]
    fn miri_scalar_kernel_subset() {
        let mut rng = Pcg64::new(11);
        for len in [0usize, 1, 7, 8, 9, 17] {
            let a: Vec<f32> = (0..len).map(|_| rng.gaussian() as f32).collect();
            let b: Vec<f32> = (0..len).map(|_| rng.gaussian() as f32).collect();
            let got = dot(&a, &b) as f64;
            let want = naive_dot_f64(&a, &b);
            assert!((got - want).abs() <= 1e-3 * (1.0 + want.abs()), "dot len={len}");
        }
        let (n, d) = (9, 5);
        let rows: Vec<f32> = (0..n * d).map(|_| rng.gaussian() as f32).collect();
        let q: Vec<f32> = (0..d).map(|_| rng.gaussian() as f32).collect();
        let got = block_max_sumexp(&rows, d, &q);
        let want = reference_max_sumexp(&rows, d, &q);
        assert_eq!(got.count, n as u64);
        assert!((got.logsumexp() - want.logsumexp()).abs() <= 1e-4);
        let mut out = vec![0f32; 2 * n];
        matvec_block_multi(&rows, d, &q.repeat(2), 2, &mut out);
        assert_eq!(&out[..n], &out[n..], "identical queries, identical lanes");
        assert_eq!(exp_f32(0.0), 1.0);
        assert!(exp_f32(1000.0).is_finite());
    }

    #[test]
    fn exp_f32_matches_libm() {
        let mut rng = Pcg64::new(1);
        for _ in 0..2000 {
            let x = (rng.next_f64() * 100.0 - 95.0) as f32; // [-95, 5]
            let got = exp_f32(x) as f64;
            let want = (x as f64).exp();
            assert!(
                (got - want).abs() <= 1e-6 * want.max(1e-30),
                "x={x}: {got} vs {want}"
            );
        }
        assert_eq!(exp_f32(0.0), 1.0);
        // the upper clamp must keep any positive input finite (the
        // exponent-bit scaling would overflow past fx = 126)
        assert!(exp_f32(86.9).is_finite());
        assert!(exp_f32(1000.0).is_finite());
    }

    #[test]
    fn ragged_lengths_match_scalar_reference() {
        // the satellite checklist's ragged sweep: 0, 1, 7, 8, 9, 63, 64,
        // 65, 300 for dot / matvec / fused reductions
        let mut rng = Pcg64::new(2);
        for len in [0usize, 1, 7, 8, 9, 63, 64, 65, 300] {
            let a: Vec<f32> = (0..len).map(|_| rng.gaussian() as f32).collect();
            let b: Vec<f32> = (0..len).map(|_| rng.gaussian() as f32).collect();
            let got = dot(&a, &b) as f64;
            let want = naive_dot_f64(&a, &b);
            assert!((got - want).abs() <= 1e-3 * (1.0 + want.abs()), "dot len={len}");

            if len > 0 {
                // matvec with d = len over a handful of rows
                let nrows = 5;
                let rows: Vec<f32> = (0..nrows * len).map(|_| rng.gaussian() as f32).collect();
                let mut out = vec![0f32; nrows];
                matvec_block(&rows, len, &a, &mut out);
                for r in 0..nrows {
                    let want = dot(&rows[r * len..(r + 1) * len], &a);
                    assert_eq!(out[r], want, "matvec len={len} row={r}");
                }
            }

            // fused reductions over `len` rows of a fixed small dim
            let d = 17;
            let rows: Vec<f32> = (0..len * d).map(|_| rng.gaussian() as f32).collect();
            let q: Vec<f32> = (0..d).map(|_| rng.gaussian() as f32).collect();
            let got = block_max_sumexp(&rows, d, &q);
            let want = reference_max_sumexp(&rows, d, &q);
            assert_eq!(got.count, len as u64, "fused count len={len}");
            if len == 0 {
                assert_eq!(got.logsumexp(), f64::NEG_INFINITY);
            } else {
                let (g, w) = (got.logsumexp(), want.logsumexp());
                assert!((g - w).abs() <= 1e-3 * (1.0 + w.abs()), "fused lse len={len}: {g} vs {w}");
            }
        }
    }

    #[test]
    fn property_simd_dot_matches_scalar() {
        Checker::new(21).cases(200).check_vec_f32(600, |xs| {
            let half = xs.len() / 2;
            let (a, b) = (&xs[..half], &xs[half..2 * half]);
            let got = dot(a, b) as f64;
            let want = dot_scalar(a, b) as f64;
            (got - want).abs() <= 1e-3 * (1.0 + want.abs())
        });
    }

    #[test]
    fn property_matvec_matches_scalar() {
        // vector = row block, param = feature dim
        Checker::new(22).cases(120).check_vec_with_param(512, 48, |xs, d| {
            let n = xs.len() / d;
            if n == 0 {
                return true;
            }
            let rows = &xs[..n * d];
            let q: Vec<f32> = (0..d).map(|j| xs[j % xs.len()] * 0.5 + j as f32 * 1e-3).collect();
            let mut got = vec![0f32; n];
            matvec_block(rows, d, &q, &mut got);
            let mut ok = true;
            for r in 0..n {
                let want = dot_scalar(&rows[r * d..(r + 1) * d], &q) as f64;
                ok &= (got[r] as f64 - want).abs() <= 1e-3 * (1.0 + want.abs());
            }
            ok
        });
    }

    #[test]
    fn property_fused_reductions_match_reference() {
        Checker::new(23).cases(80).check_vec_with_param(900, 24, |xs, d| {
            let n = xs.len() / d;
            if n == 0 {
                return true;
            }
            let rows = &xs[..n * d];
            let q: Vec<f32> = (0..d).map(|j| (j as f32 * 0.37).sin()).collect();
            let got = block_max_sumexp(rows, d, &q);
            let want = reference_max_sumexp(rows, d, &q);
            let lse_ok = (got.logsumexp() - want.logsumexp()).abs()
                <= 1e-3 * (1.0 + want.logsumexp().abs());

            let (gacc, gws) = block_expect_fragment(rows, d, &q);
            // reference expectation: exact-f64 weights at the final max
            let mut wws = vec![0f64; d];
            for r in 0..n {
                let s = dot_scalar(&rows[r * d..(r + 1) * d], &q) as f64;
                let w = (s - want.max).exp();
                for j in 0..d {
                    wws[j] += w * rows[r * d + j] as f64;
                }
            }
            let mut exp_ok = (gacc.logsumexp() - want.logsumexp()).abs()
                <= 1e-3 * (1.0 + want.logsumexp().abs());
            for j in 0..d {
                let g = gws[j] as f64 / gacc.sumexp;
                let w = wws[j] / want.sumexp;
                exp_ok &= (g - w).abs() <= 1e-3 * (1.0 + w.abs());
            }
            lse_ok && exp_ok && got.count == n as u64
        });
    }

    #[test]
    fn multi_query_bit_identical_to_single() {
        let mut rng = Pcg64::new(3);
        let (n, d) = (67, 29);
        let rows: Vec<f32> = (0..n * d).map(|_| rng.gaussian() as f32).collect();
        for nq in [1usize, 2, 3, 4, 5, 7, 8] {
            let qs: Vec<f32> = (0..nq * d).map(|_| rng.gaussian() as f32).collect();
            let mut got = vec![0f32; nq * n];
            matvec_block_multi(&rows, d, &qs, nq, &mut got);
            for j in 0..nq {
                let mut want = vec![0f32; n];
                matvec_block(&rows, d, &qs[j * d..(j + 1) * d], &mut want);
                assert_eq!(&got[j * n..(j + 1) * n], &want[..], "nq={nq} query {j}");
            }
        }
    }

    #[test]
    fn axpy_matches_scalar() {
        let mut rng = Pcg64::new(4);
        for len in [0usize, 1, 7, 8, 9, 65, 300] {
            let x: Vec<f32> = (0..len).map(|_| rng.gaussian() as f32).collect();
            let y0: Vec<f32> = (0..len).map(|_| rng.gaussian() as f32).collect();
            let mut got = y0.clone();
            axpy(0.75, &x, &mut got);
            for i in 0..len {
                let want = y0[i] + 0.75 * x[i];
                assert!((got[i] - want).abs() <= 1e-5 * (1.0 + want.abs()), "len={len} i={i}");
            }
        }
    }

    #[test]
    fn fused_running_max_rescale_is_correct() {
        // force multiple chunk-max promotions: ascending scores across
        // several CHUNK boundaries
        let d = 1;
        let n = 3 * CHUNK + 11;
        let rows: Vec<f32> = (0..n).map(|i| i as f32 * 0.01).collect();
        let q = vec![1.0f32];
        let got = block_max_sumexp(&rows, d, &q);
        let want = reference_max_sumexp(&rows, d, &q);
        assert_eq!(got.count, n as u64);
        assert!((got.logsumexp() - want.logsumexp()).abs() < 1e-4);
        // and descending (max fixed after first chunk)
        let rows: Vec<f32> = (0..n).map(|i| -(i as f32) * 0.01).collect();
        let got = block_max_sumexp(&rows, d, &q);
        let want = reference_max_sumexp(&rows, d, &q);
        assert!((got.logsumexp() - want.logsumexp()).abs() < 1e-4);
    }
}
