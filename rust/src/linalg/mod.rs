//! Dense vector/matrix kernels for the native scoring backend and
//! everything numerical off the PJRT path.
//!
//! The hot primitives — [`dot`], [`matvec_block`], [`axpy`] and the fused
//! reductions — live in [`simd`], which dispatches once at startup to
//! explicit `std::arch` kernels (AVX2+FMA on x86-64, NEON on aarch64) with
//! a portable unrolled fallback. This module re-exposes the single-query
//! entry points under their historical names and keeps the pure-f64
//! streaming [`MaxSumExp`] algebra every fragment merge builds on.
//! Everything here is allocation-free given caller-provided buffers.

pub mod pq;
pub mod quant;
pub mod simd;

/// Dot product (runtime-dispatched SIMD; see [`simd::dot`]).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    simd::dot(a, b)
}

/// Scores for a contiguous row block: `out[r] = rows[r] · q` where `rows`
/// is row-major `[nrows × d]` (runtime-dispatched SIMD).
pub fn matvec_block(rows: &[f32], d: usize, q: &[f32], out: &mut [f32]) {
    simd::matvec_block(rows, d, q, out);
}

/// `y += alpha * x` (runtime-dispatched SIMD).
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    simd::axpy(alpha, x, y);
}

/// Euclidean norm.
#[inline]
pub fn norm(x: &[f32]) -> f32 {
    dot(x, x).sqrt()
}

/// Scale in place.
#[inline]
pub fn scale(x: &mut [f32], s: f32) {
    for xi in x.iter_mut() {
        *xi *= s;
    }
}

/// Normalize to unit L2 norm (no-op on the zero vector). Returns the
/// original norm.
pub fn normalize(x: &mut [f32]) -> f32 {
    let n = norm(x);
    if n > 0.0 {
        scale(x, 1.0 / n);
    }
    n
}

/// Numerically stable log-sum-exp of `xs` (f64 accumulation).
pub fn logsumexp(xs: &[f64]) -> f64 {
    let m = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    if !m.is_finite() {
        return m;
    }
    let s: f64 = xs.iter().map(|x| (x - m).exp()).sum();
    m + s.ln()
}

/// Streaming (max, Σexp(x − max)) accumulator — merge partial partition
/// fragments from blocks without materializing all scores. This is the
/// same algebra the L1 Pallas `partition` kernel implements on-device.
#[derive(Clone, Copy, Debug)]
pub struct MaxSumExp {
    pub max: f64,
    /// Σ exp(x − max) over everything absorbed so far
    pub sumexp: f64,
    pub count: u64,
}

impl Default for MaxSumExp {
    fn default() -> Self {
        MaxSumExp { max: f64::NEG_INFINITY, sumexp: 0.0, count: 0 }
    }
}

impl MaxSumExp {
    /// Absorb one value.
    #[inline]
    pub fn push(&mut self, x: f64) {
        self.count += 1;
        if x <= self.max {
            self.sumexp += (x - self.max).exp();
        } else {
            self.sumexp = self.sumexp * (self.max - x).exp() + 1.0;
            self.max = x;
        }
    }

    /// Absorb a slice.
    pub fn push_all(&mut self, xs: &[f32]) {
        for &x in xs {
            self.push(x as f64);
        }
    }

    /// Merge another fragment (associative, order-independent up to fp
    /// rounding).
    pub fn merge(&mut self, other: &MaxSumExp) {
        if other.count == 0 {
            return;
        }
        if self.count == 0 {
            *self = *other;
            return;
        }
        if other.max <= self.max {
            self.sumexp += other.sumexp * (other.max - self.max).exp();
        } else {
            self.sumexp = self.sumexp * (self.max - other.max).exp() + other.sumexp;
            self.max = other.max;
        }
        self.count += other.count;
    }

    /// log Σ exp over everything absorbed.
    pub fn logsumexp(&self) -> f64 {
        if self.count == 0 {
            f64::NEG_INFINITY
        } else {
            self.max + self.sumexp.ln()
        }
    }
}

/// Mean of rows `ids` of a row-major `[n × d]` matrix into `out`.
pub fn mean_rows(data: &[f32], d: usize, ids: &[u32], out: &mut [f32]) {
    out.iter_mut().for_each(|x| *x = 0.0);
    for &id in ids {
        let row = &data[id as usize * d..(id as usize + 1) * d];
        axpy(1.0, row, out);
    }
    if !ids.is_empty() {
        scale(out, 1.0 / ids.len() as f32);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::Checker;
    use crate::util::rng::Pcg64;

    fn naive_dot(a: &[f32], b: &[f32]) -> f64 {
        a.iter().zip(b).map(|(&x, &y)| x as f64 * y as f64).sum()
    }

    #[test]
    fn dot_matches_naive() {
        let mut rng = Pcg64::new(1);
        for len in [0, 1, 3, 7, 8, 9, 15, 16, 64, 100, 300] {
            let a: Vec<f32> = (0..len).map(|_| rng.gaussian() as f32).collect();
            let b: Vec<f32> = (0..len).map(|_| rng.gaussian() as f32).collect();
            let got = dot(&a, &b) as f64;
            let want = naive_dot(&a, &b);
            assert!((got - want).abs() < 1e-3 * (1.0 + want.abs()), "len={len}");
        }
    }

    #[test]
    fn matvec_block_matches_per_row() {
        let mut rng = Pcg64::new(2);
        let (n, d) = (37, 19);
        let rows: Vec<f32> = (0..n * d).map(|_| rng.gaussian() as f32).collect();
        let q: Vec<f32> = (0..d).map(|_| rng.gaussian() as f32).collect();
        let mut out = vec![0f32; n];
        matvec_block(&rows, d, &q, &mut out);
        for r in 0..n {
            let want = dot(&rows[r * d..(r + 1) * d], &q);
            assert_eq!(out[r], want);
        }
    }

    #[test]
    fn logsumexp_stability() {
        // huge values must not overflow
        let v = logsumexp(&[1000.0, 1000.0]);
        assert!((v - (1000.0 + 2f64.ln())).abs() < 1e-9);
        assert_eq!(logsumexp(&[]), f64::NEG_INFINITY);
        let v = logsumexp(&[-1e30, 0.0]);
        assert!((v - 0.0).abs() < 1e-9);
    }

    #[test]
    fn maxsumexp_matches_logsumexp() {
        let mut rng = Pcg64::new(3);
        let xs: Vec<f64> = (0..500).map(|_| rng.gaussian() * 10.0).collect();
        let mut acc = MaxSumExp::default();
        for &x in &xs {
            acc.push(x);
        }
        assert!((acc.logsumexp() - logsumexp(&xs)).abs() < 1e-9);
        assert_eq!(acc.count, 500);
    }

    #[test]
    fn maxsumexp_merge_associative() {
        let mut rng = Pcg64::new(4);
        let xs: Vec<f64> = (0..300).map(|_| rng.gaussian() * 5.0).collect();
        let mut whole = MaxSumExp::default();
        xs.iter().for_each(|&x| whole.push(x));
        // split into 3 fragments, merge
        let mut a = MaxSumExp::default();
        let mut b = MaxSumExp::default();
        let mut c = MaxSumExp::default();
        xs[..100].iter().for_each(|&x| a.push(x));
        xs[100..150].iter().for_each(|&x| b.push(x));
        xs[150..].iter().for_each(|&x| c.push(x));
        let mut m = MaxSumExp::default();
        m.merge(&a);
        m.merge(&b);
        m.merge(&c);
        assert!((m.logsumexp() - whole.logsumexp()).abs() < 1e-9);
        assert_eq!(m.count, whole.count);
        // merging an empty fragment is a no-op
        m.merge(&MaxSumExp::default());
        assert!((m.logsumexp() - whole.logsumexp()).abs() < 1e-12);
    }

    #[test]
    fn normalize_unit_norm() {
        let mut v = vec![3.0f32, 4.0];
        let n = normalize(&mut v);
        assert_eq!(n, 5.0);
        assert!((norm(&v) - 1.0).abs() < 1e-6);
        let mut z = vec![0.0f32; 4];
        normalize(&mut z); // must not NaN
        assert!(z.iter().all(|x| *x == 0.0));
    }

    #[test]
    fn mean_rows_basic() {
        let data = vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]; // 3 rows × d=2
        let mut out = vec![0f32; 2];
        mean_rows(&data, 2, &[0, 2], &mut out);
        assert_eq!(out, vec![3.0, 4.0]);
    }

    #[test]
    fn property_dot_cauchy_schwarz() {
        Checker::new(11).cases(100).check_vec_f32(128, |xs| {
            let half = xs.len() / 2;
            if half == 0 {
                return true;
            }
            let (a, b) = (&xs[..half], &xs[half..2 * half]);
            let d = dot(a, b).abs() as f64;
            let bound = (norm(a) as f64) * (norm(b) as f64);
            d <= bound * (1.0 + 1e-4) + 1e-5
        });
    }

    #[test]
    fn property_maxsumexp_monotone_count() {
        Checker::new(12).cases(60).check_vec_f32(64, |xs| {
            let mut acc = MaxSumExp::default();
            acc.push_all(xs);
            // logsumexp >= max element
            let mx = xs.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
            acc.logsumexp() >= mx - 1e-9
        });
    }
}
