//! SQ8 scalar-quantized scanning — the int8 screening pass of the
//! two-stage MIPS scan.
//!
//! After the fused/batched f32 kernels (PR 1), the probe scan is pure
//! memory bandwidth: every visited row streams `4·d` bytes. This module
//! cuts that to `d` bytes by keeping a quantized shadow copy of the row
//! storage and scoring it with integer SIMD kernels; the exact f32
//! kernels then only touch the handful of rows that can still matter.
//!
//! ## Encoding
//!
//! Rows are encoded in **blocks** of [`QuantView::block`] consecutive
//! rows. Each block stores an affine `(scale, offset)` pair and every
//! value in the block becomes one u8 code:
//!
//! ```text
//! x ≈ x̂ = scale · code + offset        code = round((x − offset)/scale)
//! ```
//!
//! with `offset = min(block)` and `scale = (max − min)/255`, so the
//! per-element reconstruction error is at most `scale/2` (constant
//! blocks get `scale = 0` and reconstruct exactly). Queries are encoded
//! symmetrically to **i16** (`q ≈ s_q · u`): a query is one `d`-vector
//! per scan, so spending 2 bytes/element on it costs nothing in
//! bandwidth while making the query-side quantization error negligible
//! next to the row-side error — the quantized score is one widening
//! integer dot per row:
//!
//! ```text
//! Q = scale·s_q·(Σ_j code_j·u_j) + offset·(Σ_j q_j)
//! ```
//!
//! The `Σ_j q_j` term uses the *exact* f32 query sum, so the offset part
//! contributes no quantization error at all. The i16 range is capped so
//! the integer dot can never overflow its i32 accumulator
//! (`|Σ c_j·u_j| ≤ d·255·u_max < 2³¹`).
//!
//! ## The error-bound / overscan contract
//!
//! Writing `x_j = scale·c_j + offset + e_j` (`|e_j| ≤ scale/2`) and
//! `q_j = s_q·u_j + f_j` (`|f_j| ≤ s_q/2`), the true score satisfies
//!
//! ```text
//! |score − Q| ≤ scale·(s_q/2)·Σ_j c_j + (scale/2)·‖q‖₁ =: ε_block
//! ```
//!
//! [`QuantView::error_bound`] returns `ε = max_blocks ε_block` plus a
//! deterministic slack for the f32 kernel arithmetic itself (see its
//! docs). A two-stage scan then works as follows: pass 1
//! retains the `k·overscan` best *quantized* scores; pass 2 re-ranks all
//! retained candidates with the exact f32 kernels; finally
//! [`coverage_proved`] certifies the result. Let `q_floor` be the worst
//! retained quantized score and `T` the exact k-th score among the
//! re-ranked candidates. Every non-retained row has `Q ≤ q_floor` (top-k
//! retention) and hence an exact score `≤ q_floor + ε`; if
//! `q_floor + ε < T`, no non-retained row can reach the top-k, so the
//! re-ranked result **is** the exact top-k — bit-identical to the
//! f32-only scan, because pass 2 scores rows with the very same f32
//! kernels and [`TopK`](crate::util::topk::TopK) retention is push-order
//! independent. If the certificate fails (score ties, adversarially flat
//! data, too-small overscan), the caller falls back to the plain f32
//! scan — correctness never depends on the data being friendly.
//!
//! ## Kernels
//!
//! [`dot_u8i16`] dispatches on the same one-time CPU probe as
//! [`crate::linalg::simd`]: AVX2 widens the u8 codes to i16 lanes and
//! accumulates against the i16 query codes with `madd_epi16` (exact i32
//! arithmetic — a `maddubs`-style u8×i8 kernel is deliberately avoided
//! because `255·127·2` saturates its i16 lanes), NEON uses widening
//! `vmlal_s16` chains, and the portable fallback is an unrolled scalar
//! loop. All three produce the same exact integer, so quantized scores
//! are identical across kernels.
//!
//! ## SQ4 and multi-query batching
//!
//! [`Sq4View`] packs **4-bit** codes two per byte (⅛ of the f32 row
//! bandwidth) with the identical per-block affine scheme at 15 levels —
//! the whole error-bound/certificate algebra above carries over with the
//! wider step `scale = (max − min)/15`, so SQ4 certifies less often and
//! rides the tier ladder (see `mips::two_stage`) down to SQ8/f32 when it
//! cannot. The multi-query entry points
//! ([`QuantView::scores_batch`]/[`Sq4View::scores_batch`]) stream each
//! code block **once per batch**: the register-blocked `_x4` kernels
//! widen every row's codes once and run four queries' `madd`
//! accumulations against the shared registers (mirroring
//! `simd::matvec_block_multi` for f32), producing exactly the integers
//! the single-query kernels produce — batch output is bit-identical to
//! per-query calls.

use crate::error::Result;
use crate::linalg::simd::{self, Kernel};
use crate::store::blob::Blob;
use crate::store::format::{tag, ByteWriter, Snapshot, SnapshotWriter};

/// Rows scored per inner chunk (keeps the i32 scratch on the stack).
const QCHUNK: usize = 256;

/// Default rows per `(scale, offset)` block.
pub const DEFAULT_BLOCK: usize = 64;

/// Quantized (SQ8) shadow copy of a row-major `[n × d]` f32 matrix.
#[derive(Clone, Debug)]
pub struct QuantView {
    /// u8 codes, row-major `[n × d]` (owned or snapshot-mapped)
    codes: Blob<u8>,
    n: usize,
    d: usize,
    /// rows per (scale, offset) block
    block: usize,
    /// per-block affine parameters
    scales: Vec<f32>,
    offsets: Vec<f32>,
    /// per-block `scale · max_row(Σ_j code_j)` (error-bound ingredient)
    scaled_csums: Vec<f32>,
    /// per-block `max |x|` (fp-slack ingredient of the error bound)
    abs_maxes: Vec<f32>,
    /// `max_b scales[b]` (cached; see [`Self::error_bound`])
    max_scale: f32,
    /// `max_b scaled_csums[b]`
    max_scaled_csum: f32,
    /// `max_b abs_maxes[b]`
    max_abs: f32,
}

impl QuantView {
    /// Encode a row-major `[n × d]` matrix with `block` rows per
    /// `(scale, offset)` pair.
    pub fn encode(rows: &[f32], d: usize, block: usize) -> QuantView {
        let block = block.max(1);
        let n = if d == 0 { 0 } else { rows.len() / d };
        debug_assert_eq!(rows.len(), n * d);
        let nblocks = n.div_ceil(block);
        let mut qv = QuantView {
            codes: vec![0u8; n * d].into(),
            n,
            d,
            block,
            scales: vec![0f32; nblocks],
            offsets: vec![0f32; nblocks],
            scaled_csums: vec![0f32; nblocks],
            abs_maxes: vec![0f32; nblocks],
            max_scale: 0.0,
            max_scaled_csum: 0.0,
            max_abs: 0.0,
        };
        for b in 0..nblocks {
            qv.encode_block(rows, b);
        }
        qv.refresh_maxes();
        qv
    }

    /// Re-encode every block overlapping rows `[lo, hi)` against the
    /// current contents of `rows` (the full matrix this view shadows).
    /// This is the coherence hook for in-place row stores: after a write
    /// to rows `lo..hi`, only the touched blocks are re-quantized.
    pub fn refresh_rows(&mut self, rows: &[f32], lo: usize, hi: usize) {
        debug_assert_eq!(rows.len(), self.n * self.d);
        let hi = hi.min(self.n);
        if lo >= hi {
            return;
        }
        let b0 = lo / self.block;
        let b1 = (hi - 1) / self.block;
        for b in b0..=b1 {
            self.encode_block(rows, b);
        }
        self.refresh_maxes();
    }

    fn encode_block(&mut self, rows: &[f32], b: usize) {
        let d = self.d;
        let lo = b * self.block;
        let hi = ((b + 1) * self.block).min(self.n);
        let vals = &rows[lo * d..hi * d];
        let mut mn = f32::INFINITY;
        let mut mx = f32::NEG_INFINITY;
        let mut amax = 0f32;
        for &x in vals {
            mn = mn.min(x);
            mx = mx.max(x);
            amax = amax.max(x.abs());
        }
        // constant blocks (scale = 0): every code is 0 and the offset
        // reconstructs the value exactly
        let (scale, offset) = if mx > mn { ((mx - mn) / 255.0, mn) } else { (0.0, mn) };
        let inv = if scale > 0.0 { 1.0 / scale } else { 0.0 };
        let codes = self.codes.to_mut();
        let mut csum_max = 0u32;
        for r in lo..hi {
            let mut csum = 0u32;
            for j in 0..d {
                let x = rows[r * d + j];
                let c = if scale > 0.0 {
                    ((x - offset) * inv).round().clamp(0.0, 255.0) as u8
                } else {
                    0u8
                };
                codes[r * d + j] = c;
                csum += c as u32;
            }
            csum_max = csum_max.max(csum);
        }
        self.scales[b] = scale;
        self.offsets[b] = offset;
        self.scaled_csums[b] = scale * csum_max as f32;
        self.abs_maxes[b] = amax;
    }

    fn refresh_maxes(&mut self) {
        self.max_scale = self.scales.iter().cloned().fold(0.0, f32::max);
        self.max_scaled_csum = self.scaled_csums.iter().cloned().fold(0.0, f32::max);
        self.max_abs = self.abs_maxes.iter().cloned().fold(0.0, f32::max);
    }

    /// Number of encoded rows.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Feature dimension.
    pub fn d(&self) -> usize {
        self.d
    }

    /// Rows per quantization block.
    pub fn block(&self) -> usize {
        self.block
    }

    /// Uniform bound on `|exact score − quantized score|` valid for every
    /// encoded row against `qq`, where "exact score" means the value the
    /// **f32 kernels** compute (that is what the two-stage scan compares
    /// against). Two parts: the quantization terms from the module-doc
    /// derivation, plus a deterministic fp slack — the f32 dot
    /// accumulates ~d rounding steps over terms bounded by
    /// `max|x|·‖q‖₁·u` (`u = 2⁻²³`, generous for the FMA/multi-lane
    /// kernels), and the quantized score suffers one final f64→f32
    /// rounding of similar magnitude. Without the fp term the bound
    /// would be unsound on near-constant data, where quantization error
    /// underflows below fp noise. A 5% fudge absorbs the rounding of the
    /// bound arithmetic itself.
    pub fn error_bound(&self, qq: &QuantQuery) -> f32 {
        affine_error_bound(self.max_scaled_csum, self.max_scale, self.max_abs, self.d, qq)
    }

    /// Quantized approximate scores for an explicit (gathered) id list:
    /// `out[i] = Q_{ids[i]}`. This is the candidate-screening form the LSH
    /// families use — their candidate sets are scattered, so rows are
    /// scored one code row at a time through [`dot_u8i16`] with each
    /// row's own block parameters. Per-score arithmetic mirrors
    /// [`scores`](Self::scores) exactly (same f64 evaluation order), so a
    /// scattered score equals the contiguous score of the same row.
    pub fn scores_ids(&self, ids: &[u32], qq: &QuantQuery, out: &mut [f32]) {
        debug_assert_eq!(out.len(), ids.len());
        debug_assert_eq!(qq.codes.len(), self.d);
        let d = self.d;
        let sq = qq.scale as f64;
        let sumq = qq.sumq as f64;
        for (o, &id) in out.iter_mut().zip(ids) {
            let r = id as usize;
            debug_assert!(r < self.n);
            let b = r / self.block;
            let sc = self.scales[b] as f64 * sq;
            let off = self.offsets[b] as f64 * sumq;
            let ip = dot_u8i16(&self.codes[r * d..(r + 1) * d], &qq.codes);
            *o = (sc * ip as f64 + off) as f32;
        }
    }

    /// Quantized approximate scores for rows `[row_start, row_end)`:
    /// `out[i] = Q_{row_start + i}` (see module docs). `out.len()` must be
    /// `row_end − row_start`.
    pub fn scores(&self, row_start: usize, row_end: usize, qq: &QuantQuery, out: &mut [f32]) {
        debug_assert!(row_start <= row_end && row_end <= self.n);
        debug_assert_eq!(out.len(), row_end - row_start);
        debug_assert_eq!(qq.codes.len(), self.d);
        let d = self.d;
        let sq = qq.scale as f64;
        let sumq = qq.sumq as f64;
        let mut ibuf = [0i32; QCHUNK];
        let mut r = row_start;
        while r < row_end {
            let b = r / self.block;
            let seg_end = row_end.min((b + 1) * self.block);
            let sc = self.scales[b] as f64 * sq;
            let off = self.offsets[b] as f64 * sumq;
            let mut s = r;
            while s < seg_end {
                let e = seg_end.min(s + QCHUNK);
                let m = e - s;
                matvec_u8i16(&self.codes[s * d..e * d], d, &qq.codes, &mut ibuf[..m]);
                for (i, &ip) in ibuf[..m].iter().enumerate() {
                    out[s - row_start + i] = (sc * ip as f64 + off) as f32;
                }
                s = e;
            }
            r = seg_end;
        }
    }

    /// Multi-query quantized scores for rows `[row_start, row_end)` —
    /// query-major output: `out[j·nr + i] = Q_{row_start+i}(qqs[j])`.
    /// Each code block streams from memory once for the whole batch (the
    /// register-blocked 4-query kernel shares every row's widened codes),
    /// and each integer dot is the exact integer the single-query kernel
    /// computes, so the output is bit-identical to per-query
    /// [`scores`](Self::scores) calls.
    pub fn scores_batch(
        &self,
        row_start: usize,
        row_end: usize,
        qqs: &[&QuantQuery],
        out: &mut [f32],
    ) {
        debug_assert!(row_start <= row_end && row_end <= self.n);
        let nr = row_end - row_start;
        let nq = qqs.len();
        debug_assert_eq!(out.len(), nq * nr);
        if nq == 0 || nr == 0 {
            return;
        }
        let d = self.d;
        // allocation-free: the integer scratch covers QGROUP queries per
        // code chunk on the stack; the chunk's codes stay L1-resident
        // across query groups, so larger batches still stream each code
        // block from memory once
        const QGROUP: usize = 8;
        let mut ibuf = [0i32; QGROUP * QCHUNK];
        let mut r = row_start;
        while r < row_end {
            let b = r / self.block;
            let seg_end = row_end.min((b + 1) * self.block);
            let mut s = r;
            while s < seg_end {
                let e = seg_end.min(s + QCHUNK);
                let m = e - s;
                for (g, qgrp) in qqs.chunks(QGROUP).enumerate() {
                    let gl = qgrp.len();
                    matvec_u8i16_batch(&self.codes[s * d..e * d], d, qgrp, &mut ibuf[..gl * m]);
                    for (jj, qq) in qgrp.iter().enumerate() {
                        debug_assert_eq!(qq.codes.len(), d);
                        let sc = self.scales[b] as f64 * qq.scale as f64;
                        let off = self.offsets[b] as f64 * qq.sumq as f64;
                        let base = (g * QGROUP + jj) * nr + (s - row_start);
                        let ips = &ibuf[jj * m..(jj + 1) * m];
                        for (o, &ip) in out[base..base + m].iter_mut().zip(ips) {
                            *o = (sc * ip as f64 + off) as f32;
                        }
                    }
                }
                s = e;
            }
            r = seg_end;
        }
    }
}

/// The shared error-bound arithmetic of the affine (SQ8/SQ4) views: the
/// quantization terms from the module-doc derivation plus the
/// deterministic fp slack described on [`QuantView::error_bound`].
fn affine_error_bound(
    max_scaled_csum: f32,
    max_scale: f32,
    max_abs: f32,
    d: usize,
    qq: &QuantQuery,
) -> f32 {
    let quant = max_scaled_csum as f64 * (qq.scale as f64) * 0.5
        + max_scale as f64 * 0.5 * (qq.l1 as f64);
    let fp = (d as f64 + 2.0) * 1.2e-7 * max_abs as f64 * qq.l1 as f64;
    ((quant + fp) * 1.05 + 1e-12) as f32
}

/// A query encoded for the integer screening pass.
#[derive(Clone, Debug)]
pub struct QuantQuery {
    /// i16 codes: `q_j ≈ scale · codes[j]`
    pub codes: Vec<i16>,
    /// symmetric quantization step `s_q = max|q| / u_max`
    pub scale: f32,
    /// exact `Σ_j q_j` (pairs with the block offsets, error-free)
    pub sumq: f32,
    /// exact `‖q‖₁` (error-bound ingredient)
    pub l1: f32,
}

impl QuantQuery {
    /// Encode a query with symmetric i16 quantization. The code range is
    /// capped at `u_max = min(16383, (2³¹−1)/(255·d))` so the integer
    /// dot `Σ c_j·u_j` (u8 codes × i16 codes over `d` elements) can
    /// never overflow its i32 accumulator.
    pub fn encode(q: &[f32]) -> QuantQuery {
        let d = q.len().max(1);
        let u_max = ((i32::MAX as u64) / (255 * d as u64)).clamp(1, 16383) as f32;
        let amax = q.iter().fold(0f32, |m, &x| m.max(x.abs()));
        let scale = if amax > 0.0 { amax / u_max } else { 1.0 };
        let inv = 1.0 / scale;
        let mut sumq = 0f64;
        let mut l1 = 0f64;
        let codes: Vec<i16> = q
            .iter()
            .map(|&x| {
                sumq += x as f64;
                l1 += x.abs() as f64;
                (x * inv).round().clamp(-u_max, u_max) as i16
            })
            .collect();
        QuantQuery { codes, scale, sumq: sumq as f32, l1: l1 as f32 }
    }
}

/// The pass-2 coverage certificate of the two-stage scan (module docs):
/// `dropped` says pass 1 actually rejected or evicted pushed rows (when
/// false the retained candidates are the whole scanned set and coverage
/// is trivial), `q_floor` is the worst retained quantized score, `eps`
/// the [`QuantView::error_bound`], and `kth_exact` the exact k-th score
/// among the re-ranked candidates (a [`TopK`]'s
/// [`threshold`](crate::util::topk::TopK::threshold)). Returns true iff
/// every non-retained row provably scores strictly below the k-th exact
/// score — i.e. the re-ranked result is certified to be the exact top-k.
#[inline]
pub fn coverage_proved(dropped: bool, q_floor: f32, eps: f32, kth_exact: f32) -> bool {
    !dropped || q_floor + eps < kth_exact
}

// ---------------------------------------------------------------------------
// SQ4: packed 4-bit scalar quantization
// ---------------------------------------------------------------------------

/// Packed 4-bit (SQ4) shadow copy of a row-major `[n × d]` f32 matrix:
/// the [`QuantView`] scheme at 15 levels with two codes per byte (row
/// stride `⌈d/2⌉` bytes — ⅛ of the f32 row bandwidth). Dimension `j` of
/// a row lives in byte `j/2`, even `j` in the low nibble. Scoring and
/// the error bound mirror [`QuantView`] exactly, with
/// `scale = (max − min)/15`.
#[derive(Clone, Debug)]
pub struct Sq4View {
    /// packed nibble codes, row-major with `stride` bytes per row
    /// (owned or snapshot-mapped)
    codes: Blob<u8>,
    n: usize,
    d: usize,
    /// bytes per row = ⌈d/2⌉
    stride: usize,
    /// rows per (scale, offset) block
    block: usize,
    scales: Vec<f32>,
    offsets: Vec<f32>,
    /// per-block `scale · max_row(Σ_j code_j)`
    scaled_csums: Vec<f32>,
    abs_maxes: Vec<f32>,
    max_scale: f32,
    max_scaled_csum: f32,
    max_abs: f32,
}

impl Sq4View {
    /// Encode a row-major `[n × d]` matrix with `block` rows per
    /// `(scale, offset)` pair.
    pub fn encode(rows: &[f32], d: usize, block: usize) -> Sq4View {
        let block = block.max(1);
        let n = if d == 0 { 0 } else { rows.len() / d };
        debug_assert_eq!(rows.len(), n * d);
        let stride = d.div_ceil(2);
        let nblocks = n.div_ceil(block);
        let mut qv = Sq4View {
            codes: vec![0u8; n * stride].into(),
            n,
            d,
            stride,
            block,
            scales: vec![0f32; nblocks],
            offsets: vec![0f32; nblocks],
            scaled_csums: vec![0f32; nblocks],
            abs_maxes: vec![0f32; nblocks],
            max_scale: 0.0,
            max_scaled_csum: 0.0,
            max_abs: 0.0,
        };
        for b in 0..nblocks {
            qv.encode_block(rows, b);
        }
        qv.max_scale = qv.scales.iter().cloned().fold(0.0, f32::max);
        qv.max_scaled_csum = qv.scaled_csums.iter().cloned().fold(0.0, f32::max);
        qv.max_abs = qv.abs_maxes.iter().cloned().fold(0.0, f32::max);
        qv
    }

    fn encode_block(&mut self, rows: &[f32], b: usize) {
        let d = self.d;
        let lo = b * self.block;
        let hi = ((b + 1) * self.block).min(self.n);
        let vals = &rows[lo * d..hi * d];
        let mut mn = f32::INFINITY;
        let mut mx = f32::NEG_INFINITY;
        let mut amax = 0f32;
        for &x in vals {
            mn = mn.min(x);
            mx = mx.max(x);
            amax = amax.max(x.abs());
        }
        let (scale, offset) = if mx > mn { ((mx - mn) / 15.0, mn) } else { (0.0, mn) };
        let inv = if scale > 0.0 { 1.0 / scale } else { 0.0 };
        let stride = self.stride;
        let codes = self.codes.to_mut();
        let mut csum_max = 0u32;
        for r in lo..hi {
            let mut csum = 0u32;
            let row = &mut codes[r * stride..(r + 1) * stride];
            row.iter_mut().for_each(|x| *x = 0);
            for j in 0..d {
                let x = rows[r * d + j];
                let c = if scale > 0.0 {
                    ((x - offset) * inv).round().clamp(0.0, 15.0) as u8
                } else {
                    0u8
                };
                row[j / 2] |= if j % 2 == 0 { c } else { c << 4 };
                csum += c as u32;
            }
            csum_max = csum_max.max(csum);
        }
        self.scales[b] = scale;
        self.offsets[b] = offset;
        self.scaled_csums[b] = scale * csum_max as f32;
        self.abs_maxes[b] = amax;
    }

    /// Number of encoded rows.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Feature dimension.
    pub fn d(&self) -> usize {
        self.d
    }

    /// Rows per quantization block.
    pub fn block(&self) -> usize {
        self.block
    }

    /// Uniform bound on `|exact score − quantized score|` for every row
    /// against `qq` — the [`QuantView::error_bound`] algebra with the
    /// 15-level step.
    pub fn error_bound(&self, qq: &QuantQuery) -> f32 {
        affine_error_bound(self.max_scaled_csum, self.max_scale, self.max_abs, self.d, qq)
    }

    /// Quantized scores for an explicit (gathered) id list — the
    /// scattered candidate-screening form, per-score arithmetic identical
    /// to [`scores`](Self::scores).
    pub fn scores_ids(&self, ids: &[u32], qq: &QuantQuery, out: &mut [f32]) {
        debug_assert_eq!(out.len(), ids.len());
        debug_assert_eq!(qq.codes.len(), self.d);
        let sq = qq.scale as f64;
        let sumq = qq.sumq as f64;
        for (o, &id) in out.iter_mut().zip(ids) {
            let r = id as usize;
            debug_assert!(r < self.n);
            let b = r / self.block;
            let sc = self.scales[b] as f64 * sq;
            let off = self.offsets[b] as f64 * sumq;
            let ip =
                dot_u4i16(&self.codes[r * self.stride..(r + 1) * self.stride], self.d, &qq.codes);
            *o = (sc * ip as f64 + off) as f32;
        }
    }

    /// Quantized scores for rows `[row_start, row_end)` —
    /// `out[i] = Q_{row_start + i}`, mirroring [`QuantView::scores`].
    pub fn scores(&self, row_start: usize, row_end: usize, qq: &QuantQuery, out: &mut [f32]) {
        debug_assert!(row_start <= row_end && row_end <= self.n);
        debug_assert_eq!(out.len(), row_end - row_start);
        debug_assert_eq!(qq.codes.len(), self.d);
        let sq = qq.scale as f64;
        let sumq = qq.sumq as f64;
        let mut r = row_start;
        while r < row_end {
            let b = r / self.block;
            let seg_end = row_end.min((b + 1) * self.block);
            let sc = self.scales[b] as f64 * sq;
            let off = self.offsets[b] as f64 * sumq;
            for rr in r..seg_end {
                let ip = dot_u4i16(
                    &self.codes[rr * self.stride..(rr + 1) * self.stride],
                    self.d,
                    &qq.codes,
                );
                out[rr - row_start] = (sc * ip as f64 + off) as f32;
            }
            r = seg_end;
        }
    }

    /// Multi-query SQ4 scores — query-major
    /// `out[j·nr + i] = Q_{row_start+i}(qqs[j])`, streaming each packed
    /// code row once per batch via the register-blocked 4-query kernel.
    /// Bit-identical to per-query [`scores`](Self::scores) calls.
    pub fn scores_batch(
        &self,
        row_start: usize,
        row_end: usize,
        qqs: &[&QuantQuery],
        out: &mut [f32],
    ) {
        debug_assert!(row_start <= row_end && row_end <= self.n);
        let nr = row_end - row_start;
        let nq = qqs.len();
        debug_assert_eq!(out.len(), nq * nr);
        if nq == 0 || nr == 0 {
            return;
        }
        let mut r = row_start;
        while r < row_end {
            let b = r / self.block;
            let seg_end = row_end.min((b + 1) * self.block);
            for rr in r..seg_end {
                let row = &self.codes[rr * self.stride..(rr + 1) * self.stride];
                let i = rr - row_start;
                let mut j = 0;
                while j + 4 <= nq {
                    let s = dot_u4i16_x4(
                        row,
                        self.d,
                        &qqs[j].codes,
                        &qqs[j + 1].codes,
                        &qqs[j + 2].codes,
                        &qqs[j + 3].codes,
                    );
                    for (t, &ip) in s.iter().enumerate() {
                        let qq = qqs[j + t];
                        let sc = self.scales[b] as f64 * qq.scale as f64;
                        let off = self.offsets[b] as f64 * qq.sumq as f64;
                        out[(j + t) * nr + i] = (sc * ip as f64 + off) as f32;
                    }
                    j += 4;
                }
                while j < nq {
                    let qq = qqs[j];
                    let sc = self.scales[b] as f64 * qq.scale as f64;
                    let off = self.offsets[b] as f64 * qq.sumq as f64;
                    let ip = dot_u4i16(row, self.d, &qq.codes);
                    out[j * nr + i] = (sc * ip as f64 + off) as f32;
                    j += 1;
                }
            }
            r = seg_end;
        }
    }
}

// ---------------------------------------------------------------------------
// snapshot persistence (crate::store)
// ---------------------------------------------------------------------------

impl QuantView {
    /// Write this view as `SQ8_META` + `SQ8_CODES` sections under `arg`.
    pub(crate) fn save_sections(&self, w: &mut SnapshotWriter, arg: u32) -> Result<()> {
        let mut m = ByteWriter::default();
        m.u64(self.n as u64);
        m.u64(self.d as u64);
        m.u64(self.block as u64);
        m.slice(&self.scales);
        m.slice(&self.offsets);
        m.slice(&self.scaled_csums);
        m.slice(&self.abs_maxes);
        w.section(tag::SQ8_META, arg, m.bytes())?;
        w.section(tag::SQ8_CODES, arg, &self.codes)
    }

    /// Reopen from a snapshot; the code plane serves zero-copy when the
    /// snapshot is mapped. `None` when the sections are missing, corrupt,
    /// or shape-inconsistent — the tier ladder then degrades to the f32
    /// tier instead of refusing to serve.
    pub(crate) fn open_sections(snap: &Snapshot, arg: u32) -> Option<QuantView> {
        let mut r = snap.reader_soft(tag::SQ8_META, arg)?;
        let n = r.usize().ok()?;
        let d = r.usize().ok()?;
        let block = r.usize().ok()?;
        let scales: Vec<f32> = r.vec().ok()?;
        let offsets: Vec<f32> = r.vec().ok()?;
        let scaled_csums: Vec<f32> = r.vec().ok()?;
        let abs_maxes: Vec<f32> = r.vec().ok()?;
        let codes: Blob<u8> = snap.blob_soft(tag::SQ8_CODES, arg)?;
        if block == 0 {
            return None;
        }
        let nblocks = n.div_ceil(block);
        if codes.len() != n.checked_mul(d)?
            || scales.len() != nblocks
            || offsets.len() != nblocks
            || scaled_csums.len() != nblocks
            || abs_maxes.len() != nblocks
        {
            return None;
        }
        // recompute the cached maxes with the same fold as encode()
        let max_scale = scales.iter().cloned().fold(0.0f32, f32::max);
        let max_scaled_csum = scaled_csums.iter().cloned().fold(0.0f32, f32::max);
        let max_abs = abs_maxes.iter().cloned().fold(0.0f32, f32::max);
        Some(QuantView {
            codes,
            n,
            d,
            block,
            scales,
            offsets,
            scaled_csums,
            abs_maxes,
            max_scale,
            max_scaled_csum,
            max_abs,
        })
    }
}

impl Sq4View {
    /// Write this view as `SQ4_META` + `SQ4_CODES` sections under `arg`.
    pub(crate) fn save_sections(&self, w: &mut SnapshotWriter, arg: u32) -> Result<()> {
        let mut m = ByteWriter::default();
        m.u64(self.n as u64);
        m.u64(self.d as u64);
        m.u64(self.stride as u64);
        m.u64(self.block as u64);
        m.slice(&self.scales);
        m.slice(&self.offsets);
        m.slice(&self.scaled_csums);
        m.slice(&self.abs_maxes);
        w.section(tag::SQ4_META, arg, m.bytes())?;
        w.section(tag::SQ4_CODES, arg, &self.codes)
    }

    /// Reopen from a snapshot (soft: `None` degrades to the f32 tier).
    pub(crate) fn open_sections(snap: &Snapshot, arg: u32) -> Option<Sq4View> {
        let mut r = snap.reader_soft(tag::SQ4_META, arg)?;
        let n = r.usize().ok()?;
        let d = r.usize().ok()?;
        let stride = r.usize().ok()?;
        let block = r.usize().ok()?;
        let scales: Vec<f32> = r.vec().ok()?;
        let offsets: Vec<f32> = r.vec().ok()?;
        let scaled_csums: Vec<f32> = r.vec().ok()?;
        let abs_maxes: Vec<f32> = r.vec().ok()?;
        let codes: Blob<u8> = snap.blob_soft(tag::SQ4_CODES, arg)?;
        if block == 0 || stride != d.div_ceil(2) {
            return None;
        }
        let nblocks = n.div_ceil(block);
        if codes.len() != n.checked_mul(stride)?
            || scales.len() != nblocks
            || offsets.len() != nblocks
            || scaled_csums.len() != nblocks
            || abs_maxes.len() != nblocks
        {
            return None;
        }
        let max_scale = scales.iter().cloned().fold(0.0f32, f32::max);
        let max_scaled_csum = scaled_csums.iter().cloned().fold(0.0f32, f32::max);
        let max_abs = abs_maxes.iter().cloned().fold(0.0f32, f32::max);
        Some(Sq4View {
            codes,
            n,
            d,
            stride,
            block,
            scales,
            offsets,
            scaled_csums,
            abs_maxes,
            max_scale,
            max_scaled_csum,
            max_abs,
        })
    }
}

// ---------------------------------------------------------------------------
// integer dot kernels (u8 codes × i16 query codes → i32), dispatched on the
// same one-time CPU probe as the f32 kernels
// ---------------------------------------------------------------------------

/// Exact integer dot `Σ_j codes[j]·u[j]` (u8 × i16 → i32; overflow-free
/// by the [`QuantQuery::encode`] range cap). All kernel variants compute
/// the identical integer.
#[inline]
pub fn dot_u8i16(codes: &[u8], u: &[i16]) -> i32 {
    debug_assert_eq!(codes.len(), u.len());
    match simd::kernel() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `simd::detect()` returned Avx2 only after verifying avx2
        // on this CPU; the kernel reads exactly `min(codes.len(), u.len())`
        // elements of each slice (equal lengths are this fn's contract,
        // debug-asserted above and re-checked inside the kernel).
        Kernel::Avx2 => unsafe { avx2::dot(codes, u) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON verified by `simd::detect()`; same slice-bounds
        // argument as the AVX2 arm.
        Kernel::Neon => unsafe { neon::dot(codes, u) },
        _ => dot_u8i16_scalar(codes, u),
    }
}

/// Integer scores for a contiguous code block:
/// `out[r] = Σ_j codes[r·d + j]·u[j]`.
fn matvec_u8i16(codes: &[u8], d: usize, u: &[i16], out: &mut [i32]) {
    debug_assert_eq!(u.len(), d);
    debug_assert_eq!(codes.len(), out.len() * d);
    if d == 0 {
        out.iter_mut().for_each(|x| *x = 0);
        return;
    }
    match simd::kernel() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: avx2 verified by `simd::detect()`; the layout contract
        // (`u.len() == d`, `codes.len() == out.len()·d`) is debug-asserted
        // above and inside the kernel, which reads row `r` only at offsets
        // `r·d..r·d+d`.
        Kernel::Avx2 => unsafe { avx2::matvec(codes, d, u, out) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON verified by `simd::detect()`; same layout argument
        // as the AVX2 arm.
        Kernel::Neon => unsafe { neon::matvec(codes, d, u, out) },
        _ => {
            for (r, o) in out.iter_mut().enumerate() {
                *o = dot_u8i16_scalar(&codes[r * d..(r + 1) * d], u);
            }
        }
    }
}

/// Unrolled scalar u8×i16 dot — the dispatch fallback and the test
/// reference (4 independent accumulators, like the f32 seed kernel).
fn dot_u8i16_scalar(codes: &[u8], u: &[i16]) -> i32 {
    let n = codes.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0i32, 0i32, 0i32, 0i32);
    for c in 0..chunks {
        let i = c * 4;
        s0 += codes[i] as i32 * u[i] as i32;
        s1 += codes[i + 1] as i32 * u[i + 1] as i32;
        s2 += codes[i + 2] as i32 * u[i + 2] as i32;
        s3 += codes[i + 3] as i32 * u[i + 3] as i32;
    }
    let mut tail = 0i32;
    for i in chunks * 4..n {
        tail += codes[i] as i32 * u[i] as i32;
    }
    s0 + s1 + s2 + s3 + tail
}

/// Multi-query integer scores for a contiguous code block — query-major
/// `out[j·nrows + r] = Σ_t codes[r·d + t]·qqs[j].codes[t]`. Register-
/// blocked: each row's codes are widened once and accumulated against 4
/// queries at a time, so the batch streams the code block once instead
/// of once per query. Every integer equals the single-query kernel's.
fn matvec_u8i16_batch(codes: &[u8], d: usize, qqs: &[&QuantQuery], out: &mut [i32]) {
    let nq = qqs.len();
    if d == 0 {
        out.iter_mut().for_each(|x| *x = 0);
        return;
    }
    let nrows = codes.len() / d;
    debug_assert_eq!(codes.len(), nrows * d);
    debug_assert_eq!(out.len(), nq * nrows);
    for r in 0..nrows {
        let row = &codes[r * d..(r + 1) * d];
        let mut j = 0;
        while j + 4 <= nq {
            let s = dot_u8i16_x4(
                row,
                &qqs[j].codes,
                &qqs[j + 1].codes,
                &qqs[j + 2].codes,
                &qqs[j + 3].codes,
            );
            for (t, &ip) in s.iter().enumerate() {
                out[(j + t) * nrows + r] = ip;
            }
            j += 4;
        }
        while j < nq {
            out[j * nrows + r] = dot_u8i16(row, &qqs[j].codes);
            j += 1;
        }
    }
}

/// Four-query u8×i16 dot sharing one widening pass over the codes. All
/// kernels produce exactly the integers [`dot_u8i16`] would per query.
#[inline]
fn dot_u8i16_x4(codes: &[u8], u0: &[i16], u1: &[i16], u2: &[i16], u3: &[i16]) -> [i32; 4] {
    debug_assert!(
        codes.len() == u0.len()
            && codes.len() == u1.len()
            && codes.len() == u2.len()
            && codes.len() == u3.len()
    );
    match simd::kernel() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: avx2 verified by `simd::detect()`; all five slices have
        // equal length (debug-asserted above and re-checked inside the
        // kernel), which reads that many elements from each.
        Kernel::Avx2 => unsafe { avx2::dot_x4(codes, u0, u1, u2, u3) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON verified by `simd::detect()`; same equal-length
        // argument as the AVX2 arm.
        Kernel::Neon => unsafe { neon::dot_x4(codes, u0, u1, u2, u3) },
        _ => [
            dot_u8i16_scalar(codes, u0),
            dot_u8i16_scalar(codes, u1),
            dot_u8i16_scalar(codes, u2),
            dot_u8i16_scalar(codes, u3),
        ],
    }
}

/// Exact integer dot over one packed-nibble row:
/// `Σ_j nibble_j(codes)·u[j]` (4-bit codes × i16 query codes → i32;
/// overflow-free a fortiori under the [`QuantQuery::encode`] range cap,
/// since every code is ≤ 15 < 255). All kernels compute the identical
/// integer.
#[inline]
fn dot_u4i16(codes: &[u8], d: usize, u: &[i16]) -> i32 {
    debug_assert_eq!(codes.len(), d.div_ceil(2));
    debug_assert_eq!(u.len(), d);
    match simd::kernel() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: avx2 verified by `simd::detect()`; the packed layout
        // (`codes.len() == ⌈d/2⌉`, `u.len() == d`) is debug-asserted above
        // and inside the kernel, which touches bytes only below ⌈d/2⌉ and
        // query codes only below d.
        Kernel::Avx2 => unsafe { avx2::dot4(codes, d, u) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON verified by `simd::detect()`; same packed-layout
        // argument as the AVX2 arm.
        Kernel::Neon => unsafe { neon::dot4(codes, d, u) },
        _ => dot_u4i16_scalar(codes, d, u),
    }
}

/// Four-query packed-nibble dot sharing one unpacking pass.
#[inline]
fn dot_u4i16_x4(
    codes: &[u8],
    d: usize,
    u0: &[i16],
    u1: &[i16],
    u2: &[i16],
    u3: &[i16],
) -> [i32; 4] {
    debug_assert_eq!(codes.len(), d.div_ceil(2));
    debug_assert!(u0.len() == d && u1.len() == d && u2.len() == d && u3.len() == d);
    match simd::kernel() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: avx2 verified by `simd::detect()`; the packed layout
        // (`codes.len() == ⌈d/2⌉`, four d-length query-code slices) is
        // debug-asserted above and inside the kernel.
        Kernel::Avx2 => unsafe { avx2::dot4_x4(codes, d, u0, u1, u2, u3) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON verified by `simd::detect()`; same packed-layout
        // argument as the AVX2 arm.
        Kernel::Neon => unsafe { neon::dot4_x4(codes, d, u0, u1, u2, u3) },
        _ => [
            dot_u4i16_scalar(codes, d, u0),
            dot_u4i16_scalar(codes, d, u1),
            dot_u4i16_scalar(codes, d, u2),
            dot_u4i16_scalar(codes, d, u3),
        ],
    }
}

/// Unrolled scalar packed-nibble dot — the dispatch fallback and the
/// test reference (two independent accumulators over the nibble pair).
fn dot_u4i16_scalar(codes: &[u8], d: usize, u: &[i16]) -> i32 {
    let pairs = d / 2;
    let (mut s0, mut s1) = (0i32, 0i32);
    for p in 0..pairs {
        let b = codes[p];
        s0 += (b & 0x0f) as i32 * u[2 * p] as i32;
        s1 += (b >> 4) as i32 * u[2 * p + 1] as i32;
    }
    let mut s = s0 + s1;
    if d % 2 == 1 {
        s += (codes[pairs] & 0x0f) as i32 * u[d - 1] as i32;
    }
    s
}

// `unused_unsafe` tolerated inside the arch modules only: value-only
// `std::arch` intrinsics became safe inside `#[target_feature]` fns in
// Rust 1.87, so the explicit blocks below — required pre-1.87 under
// `deny(unsafe_op_in_unsafe_fn)` — are redundant-but-correct on newer
// toolchains (see `linalg::simd` for the full rationale).
#[cfg(target_arch = "x86_64")]
#[allow(unused_unsafe)]
mod avx2 {
    use std::arch::x86_64::*;

    /// Dispatcher invariant, re-checked (debug only) at kernel entries.
    fn feature_ok() -> bool {
        is_x86_feature_detected!("avx2")
    }

    /// Horizontal sum of the 8 i32 lanes. Value-only intrinsics.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn hsum_i32(v: __m256i) -> i32 {
        // SAFETY: value-only shuffles/adds on register operands — no
        // memory access; avx2 enabled on this fn.
        unsafe {
            let lo = _mm256_castsi256_si128(v);
            let hi = _mm256_extracti128_si256::<1>(v);
            let s = _mm_add_epi32(lo, hi);
            let s = _mm_add_epi32(s, _mm_srli_si128::<8>(s));
            let s = _mm_add_epi32(s, _mm_srli_si128::<4>(s));
            _mm_cvtsi128_si32(s)
        }
    }

    /// u8×i16 dot: widen 16 codes to i16 lanes, `madd_epi16` against the
    /// query codes, accumulate the i32 pair-sums. Exact i32 arithmetic —
    /// `madd` pair-sums stay ≤ 2·255·16383 and the total is bounded by
    /// the `QuantQuery` range cap, so nothing can saturate or wrap.
    /// Contract: `c` valid for `n` byte reads, `u` for `n` i16 reads.
    #[target_feature(enable = "avx2")]
    unsafe fn dot_raw(c: *const u8, u: *const i16, n: usize) -> i32 {
        debug_assert!(feature_ok());
        let chunks = n / 16;
        // SAFETY: value-only accumulator zeroing.
        let mut acc = unsafe { _mm256_setzero_si256() };
        for k in 0..chunks {
            let i = k * 16;
            // SAFETY: the highest element touched is i + 15 ≤ chunks·16 − 1
            // < n, so the 16-byte code load and the 16-lane i16 load stay
            // inside the buffers the contract promises; widen/madd/add are
            // value-only.
            unsafe {
                let cv = _mm256_cvtepu8_epi16(_mm_loadu_si128(c.add(i).cast::<__m128i>()));
                let uv = _mm256_loadu_si256(u.add(i).cast::<__m256i>());
                acc = _mm256_add_epi32(acc, _mm256_madd_epi16(cv, uv));
            }
        }
        // SAFETY: `hsum_i32` is value-only; avx2 enabled here.
        let mut s = unsafe { hsum_i32(acc) };
        for i in chunks * 16..n {
            // SAFETY: scalar tail, i < n — in bounds for both buffers.
            s += unsafe { *c.add(i) as i32 * *u.add(i) as i32 };
        }
        s
    }

    /// # Safety
    /// Caller must guarantee `codes.len() == u.len()` and avx2
    /// availability (guaranteed when reached through
    /// [`crate::linalg::simd::kernel`]).
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn dot(codes: &[u8], u: &[i16]) -> i32 {
        debug_assert_eq!(codes.len(), u.len());
        let n = codes.len().min(u.len());
        // SAFETY: both pointers come from live slices covering ≥ n
        // elements, satisfying `dot_raw`'s read contract.
        unsafe { dot_raw(codes.as_ptr(), u.as_ptr(), n) }
    }

    /// # Safety
    /// Caller must guarantee `u.len() == d`, `codes.len() == out.len()·d`,
    /// and avx2 availability (guaranteed via
    /// [`crate::linalg::simd::kernel`]).
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn matvec(codes: &[u8], d: usize, u: &[i16], out: &mut [i32]) {
        debug_assert_eq!(u.len(), d);
        debug_assert_eq!(codes.len(), out.len() * d);
        for (r, o) in out.iter_mut().enumerate() {
            // SAFETY: row r occupies codes[r·d .. r·d+d] — in bounds
            // because codes.len() == out.len()·d and r < out.len(); u
            // covers d elements by contract.
            *o = unsafe { dot_raw(codes.as_ptr().add(r * d), u.as_ptr(), d) };
        }
    }

    /// 4-query u8×i16 dot: each 16-code chunk is widened once and
    /// `madd`-accumulated into four per-query i32 accumulators — the
    /// register-blocked kernel behind the multi-query batch scan. Each
    /// lane follows the exact arithmetic of [`dot_raw`], so per-query
    /// integers are identical to single-query calls.
    /// Contract: `c` valid for `n` byte reads, each `u*` for `n` i16
    /// reads.
    #[target_feature(enable = "avx2")]
    unsafe fn dot_x4_raw(
        c: *const u8,
        u0: *const i16,
        u1: *const i16,
        u2: *const i16,
        u3: *const i16,
        n: usize,
    ) -> [i32; 4] {
        debug_assert!(feature_ok());
        let chunks = n / 16;
        // SAFETY: value-only accumulator zeroing.
        let (mut a0, mut a1, mut a2, mut a3) = unsafe {
            (
                _mm256_setzero_si256(),
                _mm256_setzero_si256(),
                _mm256_setzero_si256(),
                _mm256_setzero_si256(),
            )
        };
        for k in 0..chunks {
            let i = k * 16;
            // SAFETY: the highest element touched is i + 15 < n, so the
            // 16-byte code load and all four 16-lane i16 loads stay inside
            // the contract's buffers; widen/madd/add are value-only.
            unsafe {
                let cv = _mm256_cvtepu8_epi16(_mm_loadu_si128(c.add(i).cast::<__m128i>()));
                let l0 = _mm256_loadu_si256(u0.add(i).cast::<__m256i>());
                let l1 = _mm256_loadu_si256(u1.add(i).cast::<__m256i>());
                let l2 = _mm256_loadu_si256(u2.add(i).cast::<__m256i>());
                let l3 = _mm256_loadu_si256(u3.add(i).cast::<__m256i>());
                a0 = _mm256_add_epi32(a0, _mm256_madd_epi16(cv, l0));
                a1 = _mm256_add_epi32(a1, _mm256_madd_epi16(cv, l1));
                a2 = _mm256_add_epi32(a2, _mm256_madd_epi16(cv, l2));
                a3 = _mm256_add_epi32(a3, _mm256_madd_epi16(cv, l3));
            }
        }
        // SAFETY: `hsum_i32` is value-only; avx2 enabled here.
        let mut s = unsafe { [hsum_i32(a0), hsum_i32(a1), hsum_i32(a2), hsum_i32(a3)] };
        for i in chunks * 16..n {
            // SAFETY: scalar tail, i < n — in bounds for all five buffers.
            unsafe {
                let cc = *c.add(i) as i32;
                s[0] += cc * *u0.add(i) as i32;
                s[1] += cc * *u1.add(i) as i32;
                s[2] += cc * *u2.add(i) as i32;
                s[3] += cc * *u3.add(i) as i32;
            }
        }
        s
    }

    /// # Safety
    /// Caller must guarantee all five slices share one length and avx2
    /// availability (guaranteed via [`crate::linalg::simd::kernel`]).
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn dot_x4(
        codes: &[u8],
        u0: &[i16],
        u1: &[i16],
        u2: &[i16],
        u3: &[i16],
    ) -> [i32; 4] {
        debug_assert!(
            codes.len() == u0.len()
                && codes.len() == u1.len()
                && codes.len() == u2.len()
                && codes.len() == u3.len()
        );
        let n = codes.len().min(u0.len()).min(u1.len()).min(u2.len()).min(u3.len());
        // SAFETY: all five pointers come from live slices covering ≥ n
        // elements, satisfying `dot_x4_raw`'s read contract.
        unsafe {
            dot_x4_raw(codes.as_ptr(), u0.as_ptr(), u1.as_ptr(), u2.as_ptr(), u3.as_ptr(), n)
        }
    }

    /// Unpack 16 packed bytes (32 nibble codes, dim `2p` in byte `p`'s
    /// low nibble) into two i16×16 vectors in dimension order. The
    /// `srli_epi16`+mask idiom pulls high nibbles per byte; the
    /// `unpacklo/hi` interleave restores even/odd dimension order.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn unpack32(raw: __m128i) -> (__m256i, __m256i) {
        // SAFETY: value-only mask/shift/interleave/widen on register
        // operands — no memory access; avx2 enabled on this fn.
        unsafe {
            let mask = _mm_set1_epi8(0x0f);
            let lo = _mm_and_si128(raw, mask);
            let hi = _mm_and_si128(_mm_srli_epi16::<4>(raw), mask);
            let even = _mm_unpacklo_epi8(lo, hi); // dims 0..16 in order
            let odd = _mm_unpackhi_epi8(lo, hi); // dims 16..32
            (_mm256_cvtepu8_epi16(even), _mm256_cvtepu8_epi16(odd))
        }
    }

    /// Packed-nibble (SQ4) × i16 dot: 32 dims per iteration through
    /// [`unpack32`], two `madd` accumulations per chunk; scalar tail.
    /// Contract: `c` valid for `⌈d/2⌉` byte reads, `u` for `d` i16 reads.
    /// The 16-byte vector loads never read past `⌈d/2⌉`: they run only
    /// for full 32-dim chunks, i.e. bytes `k·16..k·16+16 ≤ d/2`.
    #[target_feature(enable = "avx2")]
    unsafe fn dot4_raw(c: *const u8, u: *const i16, d: usize) -> i32 {
        debug_assert!(feature_ok());
        let chunks = d / 32;
        // SAFETY: value-only accumulator zeroing.
        let mut acc = unsafe { _mm256_setzero_si256() };
        for k in 0..chunks {
            // SAFETY: k·16 + 15 < chunks·16 ≤ d/2 ≤ ⌈d/2⌉ keeps the packed
            // load inside the code row; the two i16 loads read lanes
            // k·32..k·32+32 ≤ d of `u`; unpack/madd/add are value-only.
            unsafe {
                let raw = _mm_loadu_si128(c.add(k * 16).cast::<__m128i>());
                let (cv0, cv1) = unpack32(raw);
                let uv0 = _mm256_loadu_si256(u.add(k * 32).cast::<__m256i>());
                let uv1 = _mm256_loadu_si256(u.add(k * 32 + 16).cast::<__m256i>());
                acc = _mm256_add_epi32(acc, _mm256_madd_epi16(cv0, uv0));
                acc = _mm256_add_epi32(acc, _mm256_madd_epi16(cv1, uv1));
            }
        }
        // SAFETY: `hsum_i32` is value-only; avx2 enabled here.
        let mut s = unsafe { hsum_i32(acc) };
        for j in chunks * 32..d {
            // SAFETY: scalar nibble tail — j < d means byte j/2 < ⌈d/2⌉
            // and query lane j < d, both in bounds.
            unsafe {
                let b = *c.add(j / 2);
                let nib = if j % 2 == 0 { b & 0x0f } else { b >> 4 };
                s += nib as i32 * *u.add(j) as i32;
            }
        }
        s
    }

    /// # Safety
    /// Caller must guarantee `codes.len() == ⌈d/2⌉`, `u.len() == d`, and
    /// avx2 availability (guaranteed via
    /// [`crate::linalg::simd::kernel`]).
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn dot4(codes: &[u8], d: usize, u: &[i16]) -> i32 {
        debug_assert_eq!(codes.len(), d.div_ceil(2));
        debug_assert_eq!(u.len(), d);
        // SAFETY: the slices cover ⌈d/2⌉ bytes / d lanes per this fn's
        // contract (debug-asserted above), matching `dot4_raw`'s extents.
        unsafe { dot4_raw(codes.as_ptr(), u.as_ptr(), d) }
    }

    /// 4-query packed-nibble dot: nibbles unpacked once per 32-dim chunk,
    /// `madd`-accumulated against four queries' codes.
    ///
    /// # Safety
    /// Caller must guarantee `codes.len() == ⌈d/2⌉`, each `u*.len() == d`,
    /// and avx2 availability (guaranteed via
    /// [`crate::linalg::simd::kernel`]).
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn dot4_x4(
        codes: &[u8],
        d: usize,
        u0: &[i16],
        u1: &[i16],
        u2: &[i16],
        u3: &[i16],
    ) -> [i32; 4] {
        debug_assert!(feature_ok());
        debug_assert_eq!(codes.len(), d.div_ceil(2));
        debug_assert!(u0.len() == d && u1.len() == d && u2.len() == d && u3.len() == d);
        let c = codes.as_ptr();
        let us = [u0.as_ptr(), u1.as_ptr(), u2.as_ptr(), u3.as_ptr()];
        let chunks = d / 32;
        // SAFETY: value-only accumulator zeroing.
        let mut acc = unsafe {
            [
                _mm256_setzero_si256(),
                _mm256_setzero_si256(),
                _mm256_setzero_si256(),
                _mm256_setzero_si256(),
            ]
        };
        for k in 0..chunks {
            // SAFETY: k·16 + 15 < chunks·16 ≤ d/2 ≤ codes.len() keeps the
            // packed load inside the code row; `unpack32` is value-only.
            let (cv0, cv1) = unsafe { unpack32(_mm_loadu_si128(c.add(k * 16).cast::<__m128i>())) };
            for (a, &u) in acc.iter_mut().zip(&us) {
                // SAFETY: the two i16 loads read lanes k·32..k·32+32 ≤ d of
                // each d-length query slice; madd/add are value-only.
                unsafe {
                    let uv0 = _mm256_loadu_si256(u.add(k * 32).cast::<__m256i>());
                    let uv1 = _mm256_loadu_si256(u.add(k * 32 + 16).cast::<__m256i>());
                    *a = _mm256_add_epi32(*a, _mm256_madd_epi16(cv0, uv0));
                    *a = _mm256_add_epi32(*a, _mm256_madd_epi16(cv1, uv1));
                }
            }
        }
        // SAFETY: `hsum_i32` is value-only; avx2 enabled here.
        let mut s =
            unsafe { [hsum_i32(acc[0]), hsum_i32(acc[1]), hsum_i32(acc[2]), hsum_i32(acc[3])] };
        for j in chunks * 32..d {
            // SAFETY: scalar nibble tail — j < d means byte j/2 < ⌈d/2⌉,
            // in bounds of the code row.
            let b = unsafe { *c.add(j / 2) };
            let nib = (if j % 2 == 0 { b & 0x0f } else { b >> 4 }) as i32;
            for (t, &u) in us.iter().enumerate() {
                // SAFETY: query lane j < d of a d-length slice.
                s[t] += nib * unsafe { *u.add(j) } as i32;
            }
        }
        s
    }
}

// See the `avx2` module above for why `unused_unsafe` is tolerated here.
#[cfg(target_arch = "aarch64")]
#[allow(unused_unsafe)]
mod neon {
    use std::arch::aarch64::*;

    /// Dispatcher invariant, re-checked (debug only) at kernel entries.
    fn feature_ok() -> bool {
        std::arch::is_aarch64_feature_detected!("neon")
    }

    /// u8×i16 dot via widening to i16 and `vmlal_s16` (u8 values fit
    /// i16, so the widened multiply-accumulate is exact i32 arithmetic).
    /// Contract: `c` valid for `n` byte reads, `u` for `n` i16 reads.
    #[target_feature(enable = "neon")]
    unsafe fn dot_raw(c: *const u8, u: *const i16, n: usize) -> i32 {
        debug_assert!(feature_ok());
        let chunks = n / 8;
        // SAFETY: value-only accumulator zeroing.
        let mut acc = unsafe { vdupq_n_s32(0) };
        for k in 0..chunks {
            let i = k * 8;
            // SAFETY: the highest element touched is i + 7 ≤ chunks·8 − 1
            // < n, so the 8-byte code load and the 8-lane i16 load stay
            // inside the contract's buffers; widen/mlal are value-only.
            unsafe {
                let cv = vreinterpretq_s16_u16(vmovl_u8(vld1_u8(c.add(i))));
                let uv = vld1q_s16(u.add(i));
                acc = vmlal_s16(acc, vget_low_s16(cv), vget_low_s16(uv));
                acc = vmlal_s16(acc, vget_high_s16(cv), vget_high_s16(uv));
            }
        }
        // SAFETY: value-only horizontal reduction.
        let mut s = unsafe { vaddvq_s32(acc) };
        for i in chunks * 8..n {
            // SAFETY: scalar tail, i < n — in bounds for both buffers.
            s += unsafe { *c.add(i) as i32 * *u.add(i) as i32 };
        }
        s
    }

    /// # Safety
    /// Caller must guarantee `codes.len() == u.len()` and NEON
    /// availability (guaranteed via [`crate::linalg::simd::kernel`]).
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn dot(codes: &[u8], u: &[i16]) -> i32 {
        debug_assert_eq!(codes.len(), u.len());
        let n = codes.len().min(u.len());
        // SAFETY: both pointers come from live slices covering ≥ n
        // elements, satisfying `dot_raw`'s read contract.
        unsafe { dot_raw(codes.as_ptr(), u.as_ptr(), n) }
    }

    /// # Safety
    /// Caller must guarantee `u.len() == d`, `codes.len() == out.len()·d`,
    /// and NEON availability (guaranteed via
    /// [`crate::linalg::simd::kernel`]).
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn matvec(codes: &[u8], d: usize, u: &[i16], out: &mut [i32]) {
        debug_assert_eq!(u.len(), d);
        debug_assert_eq!(codes.len(), out.len() * d);
        for (r, o) in out.iter_mut().enumerate() {
            // SAFETY: row r occupies codes[r·d .. r·d+d] — in bounds
            // because codes.len() == out.len()·d and r < out.len(); u
            // covers d elements by contract.
            *o = unsafe { dot_raw(codes.as_ptr().add(r * d), u.as_ptr(), d) };
        }
    }

    /// 4-query u8×i16 dot: codes widened once per 8-code chunk, `vmlal`
    /// chains into four per-query accumulators (register-blocked batch
    /// kernel; per-query integers identical to [`dot_raw`]).
    /// Contract: `c` valid for `n` byte reads, each `u*` for `n` i16
    /// reads.
    #[target_feature(enable = "neon")]
    unsafe fn dot_x4_raw(
        c: *const u8,
        u0: *const i16,
        u1: *const i16,
        u2: *const i16,
        u3: *const i16,
        n: usize,
    ) -> [i32; 4] {
        debug_assert!(feature_ok());
        let chunks = n / 8;
        // SAFETY: value-only accumulator zeroing.
        let mut acc = unsafe { [vdupq_n_s32(0), vdupq_n_s32(0), vdupq_n_s32(0), vdupq_n_s32(0)] };
        let us = [u0, u1, u2, u3];
        for k in 0..chunks {
            let i = k * 8;
            // SAFETY: i + 7 < n keeps the 8-byte code load inside the code
            // buffer; widen/splits are value-only.
            let (clo, chi) = unsafe {
                let cv = vreinterpretq_s16_u16(vmovl_u8(vld1_u8(c.add(i))));
                (vget_low_s16(cv), vget_high_s16(cv))
            };
            for (a, &u) in acc.iter_mut().zip(&us) {
                // SAFETY: same i + 7 < n bound for each query buffer's
                // 8-lane load; mlal is value-only.
                unsafe {
                    let uv = vld1q_s16(u.add(i));
                    *a = vmlal_s16(*a, clo, vget_low_s16(uv));
                    *a = vmlal_s16(*a, chi, vget_high_s16(uv));
                }
            }
        }
        // SAFETY: value-only horizontal reductions.
        let mut s = unsafe {
            [vaddvq_s32(acc[0]), vaddvq_s32(acc[1]), vaddvq_s32(acc[2]), vaddvq_s32(acc[3])]
        };
        for i in chunks * 8..n {
            // SAFETY: scalar tail, i < n — in bounds for all five buffers.
            let cc = unsafe { *c.add(i) } as i32;
            for (t, &u) in us.iter().enumerate() {
                // SAFETY: same i < n bound per query buffer.
                s[t] += cc * unsafe { *u.add(i) } as i32;
            }
        }
        s
    }

    /// # Safety
    /// Caller must guarantee all five slices share one length and NEON
    /// availability (guaranteed via [`crate::linalg::simd::kernel`]).
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn dot_x4(
        codes: &[u8],
        u0: &[i16],
        u1: &[i16],
        u2: &[i16],
        u3: &[i16],
    ) -> [i32; 4] {
        debug_assert!(
            codes.len() == u0.len()
                && codes.len() == u1.len()
                && codes.len() == u2.len()
                && codes.len() == u3.len()
        );
        let n = codes.len().min(u0.len()).min(u1.len()).min(u2.len()).min(u3.len());
        // SAFETY: all five pointers come from live slices covering ≥ n
        // elements, satisfying `dot_x4_raw`'s read contract.
        unsafe {
            dot_x4_raw(codes.as_ptr(), u0.as_ptr(), u1.as_ptr(), u2.as_ptr(), u3.as_ptr(), n)
        }
    }

    /// Unpack 8 packed bytes (16 nibble codes, dim `2p` in byte `p`'s low
    /// nibble) into two i16×8 vectors in dimension order (`vzip`
    /// interleave restores even/odd dims).
    #[inline]
    #[target_feature(enable = "neon")]
    unsafe fn unpack16(raw: uint8x8_t) -> (int16x8_t, int16x8_t) {
        // SAFETY: value-only mask/shift/zip/widen on register operands —
        // no memory access; NEON enabled on this fn.
        unsafe {
            let lo = vand_u8(raw, vdup_n_u8(0x0f));
            let hi = vshr_n_u8::<4>(raw);
            let even = vzip1_u8(lo, hi); // dims 0..8 in order
            let odd = vzip2_u8(lo, hi); // dims 8..16
            (
                vreinterpretq_s16_u16(vmovl_u8(even)),
                vreinterpretq_s16_u16(vmovl_u8(odd)),
            )
        }
    }

    /// Packed-nibble (SQ4) × i16 dot: 16 dims per iteration. Contract:
    /// `c` valid for `⌈d/2⌉` byte reads, `u` for `d` i16 reads; the
    /// 8-byte vector loads run only for full 16-dim chunks, i.e. bytes
    /// `k·8..k·8+8 ≤ d/2`.
    #[target_feature(enable = "neon")]
    unsafe fn dot4_raw(c: *const u8, u: *const i16, d: usize) -> i32 {
        debug_assert!(feature_ok());
        let chunks = d / 16;
        // SAFETY: value-only accumulator zeroing.
        let mut acc = unsafe { vdupq_n_s32(0) };
        for k in 0..chunks {
            // SAFETY: k·8 + 7 < chunks·8 ≤ d/2 ≤ ⌈d/2⌉ keeps the packed
            // load inside the code row; the two i16 loads read lanes
            // k·16..k·16+16 ≤ d of `u`; unpack/mlal are value-only.
            unsafe {
                let (cv0, cv1) = unpack16(vld1_u8(c.add(k * 8)));
                let uv0 = vld1q_s16(u.add(k * 16));
                let uv1 = vld1q_s16(u.add(k * 16 + 8));
                acc = vmlal_s16(acc, vget_low_s16(cv0), vget_low_s16(uv0));
                acc = vmlal_s16(acc, vget_high_s16(cv0), vget_high_s16(uv0));
                acc = vmlal_s16(acc, vget_low_s16(cv1), vget_low_s16(uv1));
                acc = vmlal_s16(acc, vget_high_s16(cv1), vget_high_s16(uv1));
            }
        }
        // SAFETY: value-only horizontal reduction.
        let mut s = unsafe { vaddvq_s32(acc) };
        for j in chunks * 16..d {
            // SAFETY: scalar nibble tail — j < d means byte j/2 < ⌈d/2⌉
            // and query lane j < d, both in bounds.
            unsafe {
                let b = *c.add(j / 2);
                let nib = if j % 2 == 0 { b & 0x0f } else { b >> 4 };
                s += nib as i32 * *u.add(j) as i32;
            }
        }
        s
    }

    /// # Safety
    /// Caller must guarantee `codes.len() == ⌈d/2⌉`, `u.len() == d`, and
    /// NEON availability (guaranteed via
    /// [`crate::linalg::simd::kernel`]).
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn dot4(codes: &[u8], d: usize, u: &[i16]) -> i32 {
        debug_assert_eq!(codes.len(), d.div_ceil(2));
        debug_assert_eq!(u.len(), d);
        // SAFETY: the slices cover ⌈d/2⌉ bytes / d lanes per this fn's
        // contract (debug-asserted above), matching `dot4_raw`'s extents.
        unsafe { dot4_raw(codes.as_ptr(), u.as_ptr(), d) }
    }

    /// 4-query packed-nibble dot: nibbles unpacked once per 16-dim chunk.
    ///
    /// # Safety
    /// Caller must guarantee `codes.len() == ⌈d/2⌉`, each `u*.len() == d`,
    /// and NEON availability (guaranteed via
    /// [`crate::linalg::simd::kernel`]).
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn dot4_x4(
        codes: &[u8],
        d: usize,
        u0: &[i16],
        u1: &[i16],
        u2: &[i16],
        u3: &[i16],
    ) -> [i32; 4] {
        debug_assert!(feature_ok());
        debug_assert_eq!(codes.len(), d.div_ceil(2));
        debug_assert!(u0.len() == d && u1.len() == d && u2.len() == d && u3.len() == d);
        let c = codes.as_ptr();
        let us = [u0.as_ptr(), u1.as_ptr(), u2.as_ptr(), u3.as_ptr()];
        let chunks = d / 16;
        // SAFETY: value-only accumulator zeroing.
        let mut acc = unsafe { [vdupq_n_s32(0), vdupq_n_s32(0), vdupq_n_s32(0), vdupq_n_s32(0)] };
        for k in 0..chunks {
            // SAFETY: k·8 + 7 < chunks·8 ≤ d/2 ≤ codes.len() keeps the
            // packed load inside the code row; `unpack16` is value-only.
            let (cv0, cv1) = unsafe { unpack16(vld1_u8(c.add(k * 8))) };
            for (a, &u) in acc.iter_mut().zip(&us) {
                // SAFETY: the two i16 loads read lanes k·16..k·16+16 ≤ d of
                // each d-length query slice; mlal is value-only.
                unsafe {
                    let uv0 = vld1q_s16(u.add(k * 16));
                    let uv1 = vld1q_s16(u.add(k * 16 + 8));
                    *a = vmlal_s16(*a, vget_low_s16(cv0), vget_low_s16(uv0));
                    *a = vmlal_s16(*a, vget_high_s16(cv0), vget_high_s16(uv0));
                    *a = vmlal_s16(*a, vget_low_s16(cv1), vget_low_s16(uv1));
                    *a = vmlal_s16(*a, vget_high_s16(cv1), vget_high_s16(uv1));
                }
            }
        }
        // SAFETY: value-only horizontal reductions.
        let mut s = unsafe {
            [vaddvq_s32(acc[0]), vaddvq_s32(acc[1]), vaddvq_s32(acc[2]), vaddvq_s32(acc[3])]
        };
        for j in chunks * 16..d {
            // SAFETY: scalar nibble tail — j < d means byte j/2 < ⌈d/2⌉,
            // in bounds of the code row.
            let b = unsafe { *c.add(j / 2) };
            let nib = (if j % 2 == 0 { b & 0x0f } else { b >> 4 }) as i32;
            for (t, &u) in us.iter().enumerate() {
                // SAFETY: query lane j < d of a d-length slice.
                s[t] += nib * unsafe { *u.add(j) } as i32;
            }
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg;
    use crate::util::check::Checker;
    use crate::util::rng::Pcg64;
    use crate::util::topk::{topk_reference, TopK};

    #[test]
    fn simd_dot_matches_scalar_on_ragged_lengths() {
        let mut rng = Pcg64::new(1);
        for len in [0usize, 1, 3, 7, 8, 9, 15, 16, 17, 31, 32, 33, 100, 300] {
            let codes: Vec<u8> = (0..len).map(|_| rng.next_below(256) as u8).collect();
            let u: Vec<i16> =
                (0..len).map(|_| (rng.next_below(32767) as i32 - 16383) as i16).collect();
            assert_eq!(dot_u8i16(&codes, &u), dot_u8i16_scalar(&codes, &u), "len={len}");
        }
    }

    #[test]
    fn simd_dot_extreme_values_are_exact() {
        // the case that breaks a maddubs-based u8×i8 kernel (i16 lane
        // saturation): all-255 codes against max-magnitude query codes
        for &uval in &[16383i16, -16383] {
            for len in [16usize, 32, 100, 512] {
                let codes = vec![255u8; len];
                let u = vec![uval; len];
                let want = 255i32 * uval as i32 * len as i32;
                assert_eq!(dot_u8i16(&codes, &u), want, "len={len} u={uval}");
            }
        }
    }

    #[test]
    fn query_code_range_prevents_i32_overflow() {
        // huge d: the range cap must shrink so Σ c·u fits i32
        let d = 100_000;
        let q = vec![1.0f32; d];
        let qq = QuantQuery::encode(&q);
        let umax = qq.codes.iter().map(|&u| (u as i32).abs()).max().unwrap();
        assert!((255u64 * umax as u64 * d as u64) < i32::MAX as u64);
        // and the codes still carry signal
        assert!(umax > 0);
    }

    #[test]
    fn property_error_bound_holds_per_row() {
        // the contract everything rests on: |exact − Q| ≤ ε for EVERY row
        Checker::new(41).cases(60).check_vec_with_param(600, 24, |xs, d| {
            let n = xs.len() / d;
            if n == 0 {
                return true;
            }
            let rows = &xs[..n * d];
            let q: Vec<f32> = (0..d).map(|j| (j as f32 * 0.7).sin() + xs[j % xs.len()]).collect();
            for block in [1usize, 3, 64] {
                let qv = QuantView::encode(rows, d, block);
                let qq = QuantQuery::encode(&q);
                let eps = qv.error_bound(&qq) as f64;
                let mut out = vec![0f32; n];
                qv.scores(0, n, &qq, &mut out);
                for r in 0..n {
                    let exact = linalg::dot(&rows[r * d..(r + 1) * d], &q) as f64;
                    if (exact - out[r] as f64).abs() > eps {
                        return false;
                    }
                }
            }
            true
        });
    }

    #[test]
    fn property_certified_pass_contains_exact_topk() {
        // whenever the coverage certificate fires, the retained candidate
        // set must contain the exact top-k
        Checker::new(42).cases(40).check_vec_with_param(900, 16, |xs, d| {
            let n = xs.len() / d;
            if n == 0 {
                return true;
            }
            let rows = &xs[..n * d];
            let q: Vec<f32> = (0..d).map(|j| (j as f32 * 0.37).cos()).collect();
            let qv = QuantView::encode(rows, d, 16);
            let qq = QuantQuery::encode(&q);
            let eps = qv.error_bound(&qq);
            let mut quant = vec![0f32; n];
            qv.scores(0, n, &qq, &mut quant);
            let mut exact = vec![0f32; n];
            linalg::matvec_block(rows, d, &q, &mut exact);
            let k = (n / 4).max(1);
            let cap = (k * 4).min(n);
            let mut tk = TopK::new(cap);
            tk.push_block(0, &quant);
            let cands = tk.into_sorted();
            let full = cands.len() == cap;
            let q_floor = cands.last().map(|s| s.score).unwrap_or(f32::NEG_INFINITY);
            // exact re-rank of the candidates
            let mut tk2 = TopK::new(k);
            for s in &cands {
                tk2.push(s.id, exact[s.id as usize]);
            }
            if !coverage_proved(full, q_floor, eps, tk2.threshold()) {
                return true; // honest refusal → caller rescans exactly
            }
            let cset: std::collections::HashSet<u32> = cands.iter().map(|s| s.id).collect();
            topk_reference(&exact, k.min(n)).iter().all(|s| cset.contains(&s.id))
        });
    }

    #[test]
    fn constant_rows_encode_exactly() {
        // scale = 0 blocks must reconstruct the constant exactly
        let d = 5;
        let rows: Vec<f32> = vec![0.75; 12 * d];
        let qv = QuantView::encode(&rows, d, 4);
        let q: Vec<f32> = vec![1.0, -2.0, 0.5, 0.0, 3.0];
        let qq = QuantQuery::encode(&q);
        let mut out = vec![0f32; 12];
        qv.scores(0, 12, &qq, &mut out);
        let want = 0.75 * q.iter().sum::<f32>();
        for (r, &got) in out.iter().enumerate() {
            assert!((got - want).abs() < 1e-5, "row {r}: {got} vs {want}");
        }
    }

    #[test]
    fn refresh_rows_tracks_in_place_updates() {
        let mut rng = Pcg64::new(7);
        let (n, d, block) = (50usize, 8usize, 16usize);
        let mut rows: Vec<f32> = (0..n * d).map(|_| rng.gaussian() as f32).collect();
        let mut qv = QuantView::encode(&rows, d, block);
        // rewrite rows 20..23 with much larger values, refresh only them
        for x in rows[20 * d..23 * d].iter_mut() {
            *x = 10.0 + rng.gaussian() as f32;
        }
        qv.refresh_rows(&rows, 20, 23);
        let fresh = QuantView::encode(&rows, d, block);
        assert_eq!(qv.codes, fresh.codes);
        assert_eq!(qv.scales, fresh.scales);
        assert_eq!(qv.offsets, fresh.offsets);
        assert_eq!(qv.max_scale, fresh.max_scale);
        assert_eq!(qv.max_scaled_csum, fresh.max_scaled_csum);
        assert_eq!(qv.max_abs, fresh.max_abs);
    }

    #[test]
    fn scores_respect_block_boundaries_and_ranges() {
        // scoring a sub-range must equal the corresponding slice of a
        // full-range scoring pass, across awkward block sizes
        let mut rng = Pcg64::new(9);
        let (n, d) = (67usize, 7usize);
        let rows: Vec<f32> = (0..n * d).map(|_| rng.gaussian() as f32).collect();
        let q: Vec<f32> = (0..d).map(|_| rng.gaussian() as f32).collect();
        let qq = QuantQuery::encode(&q);
        for block in [1usize, 5, 64, 1000] {
            let qv = QuantView::encode(&rows, d, block);
            let mut full = vec![0f32; n];
            qv.scores(0, n, &qq, &mut full);
            for (s, e) in [(0usize, 0usize), (3, 29), (29, 67), (0, 67), (66, 67)] {
                let mut part = vec![0f32; e - s];
                qv.scores(s, e, &qq, &mut part);
                assert_eq!(&part[..], &full[s..e], "block={block} range=({s},{e})");
            }
        }
    }

    #[test]
    fn scores_ids_matches_contiguous_scores() {
        // the scattered form must agree bit-for-bit with the contiguous
        // kernel on the same rows, in any gather order
        let mut rng = Pcg64::new(11);
        let (n, d) = (90usize, 11usize);
        let rows: Vec<f32> = (0..n * d).map(|_| rng.gaussian() as f32).collect();
        let q: Vec<f32> = (0..d).map(|_| rng.gaussian() as f32).collect();
        let qq = QuantQuery::encode(&q);
        for block in [1usize, 7, 64] {
            let qv = QuantView::encode(&rows, d, block);
            let mut full = vec![0f32; n];
            qv.scores(0, n, &qq, &mut full);
            let mut ids: Vec<u32> = (0..n as u32).collect();
            rng.shuffle(&mut ids);
            ids.truncate(40);
            let mut out = vec![0f32; ids.len()];
            qv.scores_ids(&ids, &qq, &mut out);
            for (i, &id) in ids.iter().enumerate() {
                assert_eq!(out[i], full[id as usize], "block={block} id={id}");
            }
        }
    }

    #[test]
    fn simd_x4_dot_matches_scalar_on_ragged_lengths() {
        // the register-blocked 4-query kernel must produce per-query
        // integers identical to the single-query scalar reference
        let mut rng = Pcg64::new(13);
        for len in [0usize, 1, 7, 15, 16, 17, 33, 100, 300] {
            let codes: Vec<u8> = (0..len).map(|_| rng.next_below(256) as u8).collect();
            let us: Vec<Vec<i16>> = (0..4)
                .map(|_| {
                    (0..len).map(|_| (rng.next_below(32767) as i32 - 16383) as i16).collect()
                })
                .collect();
            let got = dot_u8i16_x4(&codes, &us[0], &us[1], &us[2], &us[3]);
            for (t, u) in us.iter().enumerate() {
                assert_eq!(got[t], dot_u8i16_scalar(&codes, u), "len={len} q={t}");
            }
        }
    }

    #[test]
    fn simd_u4_dot_matches_scalar_on_ragged_dims() {
        // packed-nibble kernels (single and 4-query) vs the scalar
        // reference across odd dims, nibble tails, and extreme values
        let mut rng = Pcg64::new(14);
        for d in [0usize, 1, 2, 3, 15, 16, 17, 31, 32, 33, 63, 64, 100, 257] {
            let codes: Vec<u8> = (0..d.div_ceil(2)).map(|_| rng.next_below(256) as u8).collect();
            let us: Vec<Vec<i16>> = (0..4)
                .map(|_| (0..d).map(|_| (rng.next_below(32767) as i32 - 16383) as i16).collect())
                .collect();
            assert_eq!(dot_u4i16(&codes, d, &us[0]), dot_u4i16_scalar(&codes, d, &us[0]), "d={d}");
            let got = dot_u4i16_x4(&codes, d, &us[0], &us[1], &us[2], &us[3]);
            for (t, u) in us.iter().enumerate() {
                assert_eq!(got[t], dot_u4i16_scalar(&codes, d, u), "d={d} q={t}");
            }
        }
        // extreme values: all-15 nibbles against max-magnitude codes
        for d in [32usize, 100] {
            let codes = vec![0xffu8; d.div_ceil(2)];
            let u = vec![16383i16; d];
            assert_eq!(dot_u4i16(&codes, d, &u), 15 * 16383 * d as i32);
        }
    }

    #[test]
    fn property_sq4_error_bound_holds_per_row() {
        Checker::new(43).cases(60).check_vec_with_param(600, 24, |xs, d| {
            let n = xs.len() / d;
            if n == 0 {
                return true;
            }
            let rows = &xs[..n * d];
            let q: Vec<f32> = (0..d).map(|j| (j as f32 * 0.9).cos() + xs[j % xs.len()]).collect();
            for block in [1usize, 3, 64] {
                let qv = Sq4View::encode(rows, d, block);
                let qq = QuantQuery::encode(&q);
                let eps = qv.error_bound(&qq) as f64;
                let mut out = vec![0f32; n];
                qv.scores(0, n, &qq, &mut out);
                for r in 0..n {
                    let exact = linalg::dot(&rows[r * d..(r + 1) * d], &q) as f64;
                    if (exact - out[r] as f64).abs() > eps {
                        return false;
                    }
                }
            }
            true
        });
    }

    #[test]
    fn sq4_scores_ids_and_ranges_consistent() {
        let mut rng = Pcg64::new(15);
        let (n, d) = (77usize, 9usize);
        let rows: Vec<f32> = (0..n * d).map(|_| rng.gaussian() as f32).collect();
        let q: Vec<f32> = (0..d).map(|_| rng.gaussian() as f32).collect();
        let qq = QuantQuery::encode(&q);
        for block in [1usize, 5, 64] {
            let qv = Sq4View::encode(&rows, d, block);
            let mut full = vec![0f32; n];
            qv.scores(0, n, &qq, &mut full);
            for (s, e) in [(0usize, 0usize), (3, 29), (29, 77), (76, 77)] {
                let mut part = vec![0f32; e - s];
                qv.scores(s, e, &qq, &mut part);
                assert_eq!(&part[..], &full[s..e], "block={block} range=({s},{e})");
            }
            let mut ids: Vec<u32> = (0..n as u32).collect();
            rng.shuffle(&mut ids);
            ids.truncate(30);
            let mut out = vec![0f32; ids.len()];
            qv.scores_ids(&ids, &qq, &mut out);
            for (i, &id) in ids.iter().enumerate() {
                assert_eq!(out[i], full[id as usize], "block={block} id={id}");
            }
        }
    }

    #[test]
    fn scores_batch_bit_identical_to_single_query() {
        // the multi-query kernels (SQ8 and SQ4) must produce exactly the
        // single-query scores, for every batch size incl. the 4-query
        // register blocks and their remainders
        let mut rng = Pcg64::new(16);
        let (n, d) = (130usize, 37usize);
        let rows: Vec<f32> = (0..n * d).map(|_| rng.gaussian() as f32).collect();
        let qv8 = QuantView::encode(&rows, d, 24);
        let qv4 = Sq4View::encode(&rows, d, 24);
        for nq in [1usize, 2, 3, 4, 5, 8, 9] {
            let qs: Vec<Vec<f32>> = (0..nq)
                .map(|_| (0..d).map(|_| rng.gaussian() as f32).collect())
                .collect();
            let qqs: Vec<QuantQuery> = qs.iter().map(|q| QuantQuery::encode(q)).collect();
            let refs: Vec<&QuantQuery> = qqs.iter().collect();
            for (s, e) in [(0usize, n), (5, 97)] {
                let nr = e - s;
                let mut batch8 = vec![0f32; nq * nr];
                qv8.scores_batch(s, e, &refs, &mut batch8);
                let mut batch4 = vec![0f32; nq * nr];
                qv4.scores_batch(s, e, &refs, &mut batch4);
                for (j, qq) in qqs.iter().enumerate() {
                    let mut single = vec![0f32; nr];
                    qv8.scores(s, e, qq, &mut single);
                    for (a, b) in batch8[j * nr..(j + 1) * nr].iter().zip(&single) {
                        assert_eq!(a.to_bits(), b.to_bits(), "sq8 nq={nq} q={j}");
                    }
                    qv4.scores(s, e, qq, &mut single);
                    for (a, b) in batch4[j * nr..(j + 1) * nr].iter().zip(&single) {
                        assert_eq!(a.to_bits(), b.to_bits(), "sq4 nq={nq} q={j}");
                    }
                }
            }
        }
    }

    #[test]
    fn sq4_constant_rows_encode_exactly() {
        let d = 5;
        let rows: Vec<f32> = vec![-0.4; 9 * d];
        let qv = Sq4View::encode(&rows, d, 4);
        let q: Vec<f32> = vec![1.0, -2.0, 0.5, 0.0, 3.0];
        let qq = QuantQuery::encode(&q);
        let mut out = vec![0f32; 9];
        qv.scores(0, 9, &qq, &mut out);
        let want = -0.4 * q.iter().sum::<f32>();
        for (r, &got) in out.iter().enumerate() {
            assert!((got - want).abs() < 1e-5, "row {r}: {got} vs {want}");
        }
    }

    #[test]
    fn empty_and_zero_query_edge_cases() {
        let qv = QuantView::encode(&[], 4, 8);
        assert_eq!(qv.n(), 0);
        let qq = QuantQuery::encode(&[0.0; 4]);
        let mut out = [0f32; 0];
        qv.scores(0, 0, &qq, &mut out); // must not panic
        assert!(qv.error_bound(&qq) >= 0.0);
        // zero query scores everything to ~0 with a ~0 bound
        let rows = vec![1.0f32; 8];
        let qv = QuantView::encode(&rows, 4, 2);
        let mut out = [0f32; 2];
        qv.scores(0, 2, &qq, &mut out);
        assert_eq!(out, [0.0, 0.0]);
    }

    // ---- Miri-scoped subset ------------------------------------------
    // `miri_`-prefixed tests form the CI Miri lane's filter
    // (`cargo miri test --lib -- miri_`). Under Miri the dispatcher pins
    // Kernel::Scalar (cfg(miri) defaults GMIPS_FORCE_SCALAR on), so these
    // exercise the scalar dots, the SQ4 nibble pack/unpack, and the
    // encode/score round-trips with small, deterministic inputs.

    #[test]
    fn miri_scalar_dot_parity_small() {
        let mut rng = Pcg64::new(7);
        for len in [0usize, 1, 7, 8, 9, 17] {
            let codes: Vec<u8> = (0..len).map(|_| rng.next_below(256) as u8).collect();
            let u: Vec<i16> =
                (0..len).map(|_| (rng.next_below(32767) as i32 - 16383) as i16).collect();
            assert_eq!(dot_u8i16(&codes, &u), dot_u8i16_scalar(&codes, &u), "len={len}");
            // packed-nibble variant: pack `len` 4-bit codes into ⌈len/2⌉
            // bytes (even index → low nibble) and compare dispatch vs the
            // scalar reference on the same layout
            let mut packed = vec![0u8; len.div_ceil(2)];
            for (i, &c) in codes.iter().enumerate() {
                packed[i / 2] |= (c & 0x0f) << ((i % 2) * 4);
            }
            assert_eq!(
                dot_u4i16(&packed, len, &u),
                dot_u4i16_scalar(&packed, len, &u),
                "len={len}"
            );
        }
    }

    #[test]
    fn miri_sq4_nibble_pack_roundtrip_odd_dims() {
        // odd dims exercise the half-byte tail: byte ⌈d/2⌉−1 carries only
        // a low nibble, the adversarial case for OOB / uninit reads
        for d in [1usize, 3, 5, 7, 15, 17] {
            let n = 4;
            let rows: Vec<f32> =
                (0..n * d).map(|i| ((i * 37 % 97) as f32 / 96.0) * 2.0 - 1.0).collect();
            let qv = Sq4View::encode(&rows, d, 2);
            assert_eq!(qv.n(), n);
            let q: Vec<f32> = (0..d).map(|j| (j as f32 * 0.3).cos()).collect();
            let qq = QuantQuery::encode(&q);
            let eps = qv.error_bound(&qq) as f64;
            let mut out = vec![0f32; n];
            qv.scores(0, n, &qq, &mut out);
            for r in 0..n {
                let exact = linalg::dot(&rows[r * d..(r + 1) * d], &q) as f64;
                assert!(
                    (exact - out[r] as f64).abs() <= eps,
                    "d={d} row={r}: |{exact} - {}| > {eps}",
                    out[r]
                );
            }
        }
    }

    #[test]
    fn miri_quant_encode_score_roundtrip() {
        let d = 6;
        let rows: Vec<f32> = (0..5 * d).map(|i| (i as f32 * 0.11).sin()).collect();
        let qv = QuantView::encode(&rows, d, 2);
        let q: Vec<f32> = (0..d).map(|j| 0.5 - j as f32 * 0.1).collect();
        let qq = QuantQuery::encode(&q);
        let eps = qv.error_bound(&qq) as f64;
        let mut out = vec![0f32; 5];
        qv.scores(0, 5, &qq, &mut out);
        for r in 0..5 {
            let exact = linalg::dot(&rows[r * d..(r + 1) * d], &q) as f64;
            assert!((exact - out[r] as f64).abs() <= eps, "row {r}");
        }
    }
}
