//! Random walk over the dataset (paper §4.2.2).
//!
//! Markov chain with transition
//! `Pr(X_{t+1} = i | X_t = j) ∝ exp(τ⁻¹-scaled φ(x_i)·φ(x_j))` — a
//! PageRank-flavored diffusion where each step is one log-linear sampling
//! query whose parameter is the current state's feature vector. The MIPS
//! structure is reused across all steps while the naive sampler gets no
//! caching (storing all n×n transition rows would be terabytes — the
//! paper's motivation for this experiment).
//!
//! Quality metric: overlap of the top-1000 most-visited states between
//! chains (between-chain vs within-chain windows).

use crate::data::Dataset;
use crate::linalg;
use crate::sampler::Sampler;
use crate::util::rng::Pcg64;
use crate::util::stats;
use std::sync::Arc;

/// Result of one chain run.
#[derive(Clone, Debug)]
pub struct WalkResult {
    /// visit counts per state
    pub visits: Vec<u64>,
    /// number of steps taken
    pub steps: usize,
    /// total rows scanned by the sampler (work metric)
    pub scanned: u64,
    /// lazily-sampled tail Gumbels (work metric; 0 for exact)
    pub tail_m: u64,
}

impl WalkResult {
    /// Visit counts of the first/second half windows — the paper's
    /// within-chain stability measure.
    pub fn half_windows(&self, trace: &[u32]) -> (Vec<u64>, Vec<u64>) {
        let n = self.visits.len();
        let mid = trace.len() / 2;
        let mut a = vec![0u64; n];
        let mut b = vec![0u64; n];
        for &s in &trace[..mid] {
            a[s as usize] += 1;
        }
        for &s in &trace[mid..] {
            b[s as usize] += 1;
        }
        (a, b)
    }
}

/// Random-walk driver over any [`Sampler`].
pub struct RandomWalk {
    ds: Arc<Dataset>,
    /// inverse temperature folded into the per-step query: q = φ(x_t)/τ
    pub inv_temperature: f32,
}

impl RandomWalk {
    pub fn new(ds: Arc<Dataset>, temperature: f64) -> Self {
        RandomWalk { ds, inv_temperature: (1.0 / temperature) as f32 }
    }

    /// Run `steps` transitions with the given sampler, returning visit
    /// counts and the full trace.
    pub fn run(
        &self,
        sampler: &dyn Sampler,
        steps: usize,
        rng: &mut Pcg64,
    ) -> (WalkResult, Vec<u32>) {
        let n = self.ds.n;
        let mut visits = vec![0u64; n];
        let mut trace = Vec::with_capacity(steps);
        let mut state = rng.next_below(n as u64) as u32;
        let mut scanned = 0u64;
        let mut tail_m = 0u64;
        let mut q = vec![0f32; self.ds.d];
        for _ in 0..steps {
            q.copy_from_slice(self.ds.row(state as usize));
            linalg::scale(&mut q, self.inv_temperature);
            let out = sampler.sample(&q, rng);
            state = out.id;
            visits[state as usize] += 1;
            trace.push(state);
            scanned += out.work.scanned as u64;
            tail_m += out.work.m as u64;
        }
        (WalkResult { visits, steps, scanned, tail_m }, trace)
    }

    /// The paper's §4.2.2 comparison: run an exact chain and an
    /// approximate chain, report (between-chain, within-exact,
    /// within-approx) top-k overlaps.
    pub fn compare(
        &self,
        exact: &dyn Sampler,
        approx: &dyn Sampler,
        steps: usize,
        top: usize,
        seed: u64,
    ) -> WalkComparison {
        let mut rng_a = Pcg64::new_stream(seed, 1);
        let mut rng_b = Pcg64::new_stream(seed, 2);
        let (res_exact, trace_e) = self.run(exact, steps, &mut rng_a);
        let (res_approx, trace_a) = self.run(approx, steps, &mut rng_b);
        let between = stats::topk_overlap(&res_exact.visits, &res_approx.visits, top);
        let (e1, e2) = res_exact.half_windows(&trace_e);
        let (a1, a2) = res_approx.half_windows(&trace_a);
        WalkComparison {
            between_chain: between,
            within_exact: stats::topk_overlap(&e1, &e2, top),
            within_approx: stats::topk_overlap(&a1, &a2, top),
            exact_scanned: res_exact.scanned,
            approx_scanned: res_approx.scanned,
            steps,
            top,
        }
    }
}

/// §4.2.2 summary numbers.
#[derive(Clone, Copy, Debug)]
pub struct WalkComparison {
    /// top-k overlap between the exact and approximate chains
    /// (paper: 73.6%)
    pub between_chain: f64,
    /// top-k overlap between two windows of the exact chain (69.3%)
    pub within_exact: f64,
    /// …and of the approximate chain (72.9%)
    pub within_approx: f64,
    pub exact_scanned: u64,
    pub approx_scanned: u64,
    pub steps: usize,
    pub top: usize,
}

impl WalkComparison {
    /// The paper's acceptance criterion: between-chain differences are
    /// comparable to within-chain differences (finite-sample noise), i.e.
    /// the approximate chain has the same stationary behaviour.
    pub fn chains_equivalent(&self, slack: f64) -> bool {
        let within_floor = self.within_exact.min(self.within_approx);
        self.between_chain >= within_floor - slack
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::mips::brute::BruteForce;
    use crate::sampler::exact::ExactSampler;
    use crate::sampler::lazy_gumbel::LazyGumbelSampler;
    use crate::scorer::{NativeScorer, ScoreBackend};

    fn setup(n: usize, seed: u64) -> (Arc<Dataset>, Arc<dyn ScoreBackend>) {
        let ds = Arc::new(synth::imagenet_like(n, 8, 10, 0.3, seed));
        let backend: Arc<dyn ScoreBackend> = Arc::new(NativeScorer);
        (ds, backend)
    }

    #[test]
    fn chain_visits_count_correctly() {
        let (ds, backend) = setup(400, 1);
        let sampler = ExactSampler::new(ds.clone(), backend);
        let walk = RandomWalk::new(ds, 0.2);
        let mut rng = Pcg64::new(2);
        let (res, trace) = walk.run(&sampler, 500, &mut rng);
        assert_eq!(res.steps, 500);
        assert_eq!(trace.len(), 500);
        assert_eq!(res.visits.iter().sum::<u64>(), 500);
        assert_eq!(res.scanned, 500 * 400);
    }

    #[test]
    fn exact_vs_lazy_chains_equivalent() {
        // the paper's §4.2.2 conclusion, at test scale
        let (ds, backend) = setup(400, 3);
        let index = Arc::new(BruteForce::new(ds.clone(), backend.clone()));
        let exact = ExactSampler::new(ds.clone(), backend.clone());
        let lazy = LazyGumbelSampler::new(ds.clone(), index, backend.clone(), 60, 0.0);
        let walk = RandomWalk::new(ds, 0.2);
        let cmp = walk.compare(&exact, &lazy, 8_000, 40, 7);
        // between-chain overlap is finite-sample noisy; the paper's
        // criterion is *relative*: between ≈ within (chains_equivalent)
        assert!(cmp.between_chain > 0.1, "between {}", cmp.between_chain);
        assert!(
            cmp.chains_equivalent(0.15),
            "between {} within ({}, {})",
            cmp.between_chain,
            cmp.within_exact,
            cmp.within_approx
        );
    }

    #[test]
    fn half_windows_partition_trace() {
        let (ds, backend) = setup(100, 5);
        let sampler = ExactSampler::new(ds.clone(), backend);
        let walk = RandomWalk::new(ds, 0.3);
        let mut rng = Pcg64::new(6);
        let (res, trace) = walk.run(&sampler, 200, &mut rng);
        let (a, b) = res.half_windows(&trace);
        assert_eq!(a.iter().sum::<u64>(), 100);
        assert_eq!(b.iter().sum::<u64>(), 100);
    }

    use crate::util::rng::Pcg64;
}
