//! Maximum-likelihood learning of the log-linear model (paper §4.4,
//! Table 2 / Figure 5).
//!
//! Objective: `θ* = argmax_θ Σ_{x∈D} log Pr(x; θ)` over a small coherent
//! subset `D ⊂ X` (the paper hand-picks 16 "water" images; we draw 16
//! vectors from one latent generator cluster — same property: a coherent
//! subset sharing an attribute).
//!
//! Gradient: `∇ = Σ_{x∈D} φ(x) − |D|·E_θ[φ]`. Three ways to get
//! `E_θ[φ]`:
//!
//! * [`GradMethod::Exact`] — full scan (the 1× baseline),
//! * [`GradMethod::TopK`] — truncate to the top-k (fast but biased; the
//!   paper shows it cannot optimize the objective),
//! * [`GradMethod::Amortized`] — **Algorithm 4** (ours; paper: 9.6×
//!   speedup with a learning curve indistinguishable from exact).
//!
//! Gradient ascent with the paper's schedule: constant `α` halved every
//! `lr_halve_every` iterations.

use crate::config::LearnConfig;
use crate::data::Dataset;
use crate::dispatch::ExpectationDispatch;
use crate::error::Result;
use crate::estimator::expectation::{exact_feature_expectation, ExpectationEstimator};
use crate::linalg;
use crate::mips::{BuiltIndex, MipsIndex};
use crate::scorer::ScoreBackend;
use crate::shard::{ShardedExpectationEstimator, ShardedIndex};
use crate::util::rng::Pcg64;
use std::sync::Arc;
use std::time::Instant;

/// Gradient estimation method.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GradMethod {
    Exact,
    TopK,
    Amortized,
}

impl GradMethod {
    pub fn name(&self) -> &'static str {
        match self {
            GradMethod::Exact => "exact",
            GradMethod::TopK => "top-k",
            GradMethod::Amortized => "ours",
        }
    }
}

/// One point on the learning curve.
#[derive(Clone, Copy, Debug)]
pub struct CurvePoint {
    pub iter: usize,
    /// exact mean log-likelihood over D (evaluation is always exact so
    /// curves are comparable across methods)
    pub log_likelihood: f64,
}

/// Outcome of a training run.
#[derive(Clone, Debug)]
pub struct LearnResult {
    pub method: GradMethod,
    pub theta: Vec<f32>,
    pub curve: Vec<CurvePoint>,
    /// final exact mean log-likelihood
    pub final_ll: f64,
    /// wall time spent in *gradient computation* only (the quantity the
    /// paper's speedup column measures; exact-LL evaluation is excluded)
    pub grad_seconds: f64,
    pub iters: usize,
}

/// MLE trainer bound to a database.
pub struct Learner {
    ds: Arc<Dataset>,
    index: Arc<dyn MipsIndex>,
    /// the concrete sharded index when training over one —
    /// [`GradMethod::Amortized`] then runs the sharded Algorithm 4
    sharded: Option<Arc<ShardedIndex>>,
    backend: Arc<dyn ScoreBackend>,
    cfg: LearnConfig,
    /// training subset D (ids into ds)
    pub train_ids: Vec<u32>,
    /// Σ_{x∈D} φ(x) / |D| — the data term, precomputed
    data_mean: Vec<f32>,
}

impl Learner {
    /// Pick `D` as `train_size` members of one latent cluster (the
    /// "water images" analog), or uniformly if the dataset has no labels.
    ///
    /// `index` accepts anything convertible into a [`BuiltIndex`]; pass
    /// the [`crate::mips::build_index_typed`] result (or an
    /// `Arc<ShardedIndex>`) so sharded MLE training routes its
    /// Algorithm 4 gradients through the sharded estimator — a plain
    /// `Arc<dyn MipsIndex>` trains with the monolithic one.
    pub fn new(
        ds: Arc<Dataset>,
        index: impl Into<BuiltIndex>,
        backend: Arc<dyn ScoreBackend>,
        cfg: LearnConfig,
    ) -> Result<Self> {
        let built = index.into();
        let mut rng = Pcg64::new(cfg.seed);
        let train_ids = pick_coherent_subset(&ds, cfg.train_size, &mut rng);
        let mut data_mean = vec![0f32; ds.d];
        linalg::mean_rows(&ds.data, ds.d, &train_ids, &mut data_mean);
        Ok(Learner {
            ds,
            index: built.as_dyn(),
            sharded: built.sharded().cloned(),
            backend,
            cfg,
            train_ids,
            data_mean,
        })
    }

    /// Exact mean log-likelihood of D under θ (evaluation; full scan).
    pub fn exact_ll(&self, theta: &[f32]) -> f64 {
        let log_z =
            crate::estimator::partition::exact_log_partition(&self.ds, self.backend.as_ref(), theta);
        let mean_score: f64 = self
            .train_ids
            .iter()
            .map(|&id| linalg::dot(self.ds.row(id as usize), theta) as f64)
            .sum::<f64>()
            / self.train_ids.len() as f64;
        mean_score - log_z
    }

    /// Run gradient ascent with the given method. `rng` drives the
    /// stochastic estimators (and nothing else).
    pub fn train(&self, method: GradMethod, rng: &mut Pcg64) -> LearnResult {
        let d = self.ds.d;
        let n = self.ds.n;
        let sqrt_n = (n as f64).sqrt();
        let k_ours = ((self.cfg.k_mult * sqrt_n).round() as usize).clamp(1, n);
        let l_ours = ((self.cfg.l_ratio * k_ours as f64).round() as usize).max(1);
        let k_topk = ((self.cfg.topk_mult * sqrt_n).round() as usize).clamp(1, n);

        // "ours" routes through the sharded Algorithm 4 when training
        // over a sharded index (keyed per-shard tail draws, weighted-LSE
        // merge); the top-k baseline is head-only, so the plain estimator
        // over the (possibly sharded) index is already exact for it
        let est_ours = match &self.sharded {
            // fold the caller's rng into the stream seed so `rng` drives
            // the sharded estimator exactly as documented — distinct rng
            // states give distinct (still replayable) keyed tail draws,
            // instead of every run replaying cfg.seed's rounds 0, 1, …
            Some(idx) => ExpectationDispatch::Sharded(ShardedExpectationEstimator::new(
                self.ds.clone(),
                idx.clone(),
                self.backend.clone(),
                k_ours,
                l_ours,
                self.cfg.seed ^ rng.next_u64(),
            )),
            None => ExpectationDispatch::Mono(ExpectationEstimator::new(
                self.ds.clone(),
                self.index.clone(),
                self.backend.clone(),
                k_ours,
                l_ours,
            )),
        };
        let est_topk = ExpectationEstimator::new(
            self.ds.clone(),
            self.index.clone(),
            self.backend.clone(),
            k_topk,
            1,
        );

        let mut theta = vec![0f32; d];
        let mut curve = Vec::new();
        let mut grad_seconds = 0f64;
        let mut lr = self.cfg.lr;
        for it in 0..self.cfg.iters {
            if it > 0 && self.cfg.lr_halve_every > 0 && it % self.cfg.lr_halve_every == 0 {
                lr *= 0.5;
            }
            if it % self.cfg.eval_every == 0 {
                curve.push(CurvePoint { iter: it, log_likelihood: self.exact_ll(&theta) });
            }
            let t0 = Instant::now();
            let model_mean: Vec<f32> = match method {
                GradMethod::Exact => {
                    exact_feature_expectation(&self.ds, self.backend.as_ref(), &theta).0
                }
                GradMethod::TopK => est_topk.expect_features_topk_only(&theta).mean,
                GradMethod::Amortized => est_ours.expect_features(&theta, rng).mean,
            };
            grad_seconds += t0.elapsed().as_secs_f64();
            // θ += α (mean φ(D) − E_θ[φ])
            for j in 0..d {
                theta[j] += (lr as f32) * (self.data_mean[j] - model_mean[j]);
            }
        }
        let final_ll = self.exact_ll(&theta);
        curve.push(CurvePoint { iter: self.cfg.iters, log_likelihood: final_ll });
        LearnResult { method, theta, curve, final_ll, grad_seconds, iters: self.cfg.iters }
    }

    /// Top `count` most probable states under θ, excluding D (Figure 6's
    /// "most probable images outside the training set").
    pub fn top_samples(&self, theta: &[f32], count: usize) -> Vec<u32> {
        let top = self.index.top_k(theta, count + self.train_ids.len());
        let d_set: rustc_hash::FxHashSet<u32> = self.train_ids.iter().copied().collect();
        top.items
            .iter()
            .map(|s| s.id)
            .filter(|id| !d_set.contains(id))
            .take(count)
            .collect()
    }

    /// Fraction of `ids` sharing the dominant latent cluster of D —
    /// quantifies Figure 6's "semantically similar to the training set".
    pub fn cluster_purity(&self, ids: &[u32]) -> f64 {
        if self.ds.labels.is_empty() || ids.is_empty() {
            return 0.0;
        }
        // dominant label of D
        let mut counts: rustc_hash::FxHashMap<u32, usize> = rustc_hash::FxHashMap::default();
        for &id in &self.train_ids {
            *counts.entry(self.ds.labels[id as usize]).or_insert(0) += 1;
        }
        let dom = counts.into_iter().max_by_key(|&(_, c)| c).map(|(l, _)| l).unwrap();
        ids.iter().filter(|&&id| self.ds.labels[id as usize] == dom).count() as f64
            / ids.len() as f64
    }
}

/// Choose a coherent training subset: `size` members of the most populous
/// latent cluster (falls back to a uniform draw for unlabeled data).
fn pick_coherent_subset(ds: &Dataset, size: usize, rng: &mut Pcg64) -> Vec<u32> {
    let size = size.clamp(1, ds.n);
    if ds.labels.is_empty() {
        let excl = rustc_hash::FxHashSet::default();
        return rng.distinct_excluding(ds.n as u64, size, &excl);
    }
    // histogram of labels
    let mut counts: rustc_hash::FxHashMap<u32, usize> = rustc_hash::FxHashMap::default();
    for &l in &ds.labels {
        *counts.entry(l).or_insert(0) += 1;
    }
    let (dominant, _) = counts
        .into_iter()
        .filter(|&(_, c)| c >= size)
        .max_by_key(|&(_, c)| c)
        .unwrap_or((ds.labels[0], 0));
    let members: Vec<u32> = (0..ds.n as u32)
        .filter(|&i| ds.labels[i as usize] == dominant)
        .collect();
    if members.len() <= size {
        return members;
    }
    let mut picks = members;
    rng.shuffle(&mut picks);
    picks.truncate(size);
    picks
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::data::synth;
    use crate::mips::brute::BruteForce;
    use crate::scorer::NativeScorer;

    fn quick_cfg(iters: usize) -> LearnConfig {
        let mut c = Config::default().learn;
        c.iters = iters;
        c.eval_every = iters.max(1);
        c.lr = 4.0;
        c.lr_halve_every = iters / 2 + 1;
        c.train_size = 8;
        c.k_mult = 5.0;
        c.l_ratio = 5.0;
        // at test scale (n≈1500) the paper's 100√n would cover the whole
        // dataset; keep top-k to ~2.5% of states so its bias is visible,
        // matching the paper's regime (100√n / 1.28M ≈ 8.8%)
        c.topk_mult = 1.0;
        c
    }

    fn setup(n: usize, seed: u64) -> Learner {
        let ds = Arc::new(synth::imagenet_like(n, 8, 10, 0.25, seed));
        let backend: Arc<dyn ScoreBackend> = Arc::new(NativeScorer);
        let index: Arc<dyn MipsIndex> = Arc::new(BruteForce::new(ds.clone(), backend.clone()));
        Learner::new(ds, index, backend, quick_cfg(60)).unwrap()
    }

    #[test]
    fn training_subset_is_coherent() {
        let learner = setup(2000, 1);
        assert_eq!(learner.train_ids.len(), 8);
        let labels: rustc_hash::FxHashSet<u32> = learner
            .train_ids
            .iter()
            .map(|&id| learner.ds.labels[id as usize])
            .collect();
        assert_eq!(labels.len(), 1, "D must come from one cluster");
    }

    #[test]
    fn exact_training_improves_likelihood() {
        let learner = setup(1500, 2);
        let mut rng = Pcg64::new(3);
        let res = learner.train(GradMethod::Exact, &mut rng);
        let ll0 = res.curve.first().unwrap().log_likelihood;
        assert!(res.final_ll > ll0 + 0.5, "LL {ll0} → {} did not improve", res.final_ll);
        // LL at θ=0 is exactly −ln n
        assert!((ll0 + (1500f64).ln()).abs() < 1e-6);
    }

    #[test]
    fn amortized_tracks_exact_and_topk_lags() {
        let learner = setup(1500, 4);
        let mut rng = Pcg64::new(5);
        let exact = learner.train(GradMethod::Exact, &mut rng);
        let ours = learner.train(GradMethod::Amortized, &mut rng);
        let topk = learner.train(GradMethod::TopK, &mut rng);
        // paper Table 2 ordering: exact ≈ ours > top-k
        assert!(
            (ours.final_ll - exact.final_ll).abs() < 0.25,
            "ours {} vs exact {}",
            ours.final_ll,
            exact.final_ll
        );
        assert!(
            topk.final_ll < exact.final_ll - 0.1,
            "top-k {} should lag exact {}",
            topk.final_ll,
            exact.final_ll
        );
    }

    #[test]
    fn top_samples_exclude_training_set_and_are_pure() {
        let learner = setup(2000, 6);
        let mut rng = Pcg64::new(7);
        let res = learner.train(GradMethod::Exact, &mut rng);
        let tops = learner.top_samples(&res.theta, 10);
        assert_eq!(tops.len(), 10);
        for id in &tops {
            assert!(!learner.train_ids.contains(id));
        }
        let purity = learner.cluster_purity(&tops);
        assert!(purity > 0.5, "top samples purity {purity}");
    }

    #[test]
    fn grad_time_accounted() {
        let learner = setup(800, 8);
        let mut rng = Pcg64::new(9);
        let res = learner.train(GradMethod::Exact, &mut rng);
        assert!(res.grad_seconds > 0.0);
        assert_eq!(res.iters, 60);
        assert!(res.curve.len() >= 2);
    }

    use crate::util::rng::Pcg64;
}
