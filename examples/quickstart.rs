//! Quickstart: build everything from a preset, then run each of the
//! paper's query types once.
//!
//!     cargo run --release --example quickstart
//!
//! Uses the native backend so it works before `make artifacts`; pass
//! `--pjrt` (after `make artifacts`) to run the scoring through the
//! AOT-compiled XLA executables instead.

use gmips::prelude::*;
use gmips::runtime::PjrtScorer;
use std::sync::Arc;

fn main() -> Result<()> {
    let use_pjrt = std::env::args().any(|a| a == "--pjrt");

    // 1. configuration — the paper's ImageNet-like setting, scaled down
    let mut cfg = Config::preset("imagenet")?;
    cfg.data.n = 50_000;
    cfg.data.d = 64;
    println!("dataset: {} rows × {} dims ({})", cfg.data.n, cfg.data.d, cfg.data.kind.name());

    // 2. data + scoring backend + MIPS index (the one-time preprocessing
    //    that all queries amortize over)
    let ds = Arc::new(gmips::data::generate(&cfg.data));
    let backend: Arc<dyn ScoreBackend> = if use_pjrt {
        println!("backend: PJRT (AOT artifacts)");
        Arc::new(PjrtScorer::load("artifacts")?)
    } else {
        println!("backend: native");
        Arc::new(NativeScorer)
    };
    let index = build_index(&ds, &cfg.index, backend.clone())?;
    println!("index:   {}", index.describe());

    let mut rng = Pcg64::new(7);
    let theta = gmips::data::random_theta(&ds, cfg.data.temperature, &mut rng);

    // 3. sampling (Algorithm 1): exact softmax samples in sublinear time
    let sampler =
        LazyGumbelSampler::new(ds.clone(), index.clone(), backend.clone(), cfg.sampler_k(), 0.0);
    let outs = sampler.sample_many(&theta, 5, &mut rng);
    println!(
        "samples: {:?} (scanned {} of {} rows, k={}, lazy tail Gumbels per draw ≈ {})",
        outs.iter().map(|o| o.id).collect::<Vec<_>>(),
        outs[0].work.scanned,
        ds.n,
        outs[0].work.k,
        outs.iter().map(|o| o.work.m).sum::<usize>() / outs.len()
    );

    // 4. partition function (Algorithm 3) vs exact
    let est = PartitionEstimator::new(
        ds.clone(),
        index.clone(),
        backend.clone(),
        cfg.estimator_k(),
        cfg.estimator_l(),
    );
    let log_z = est.estimate(&theta, &mut rng).log_z;
    let exact = gmips::estimator::partition::exact_log_partition(&ds, backend.as_ref(), &theta);
    println!(
        "log Z:   estimate {:.4} vs exact {:.4} (relative error {:.2e})",
        log_z,
        exact,
        ((log_z - exact).exp() - 1.0).abs()
    );

    // 5. feature expectation (Algorithm 4) — the gradient engine
    let expect = ExpectationEstimator::new(
        ds.clone(),
        index.clone(),
        backend.clone(),
        cfg.estimator_k(),
        cfg.estimator_l(),
    );
    let e = expect.expect_features(&theta, &mut rng);
    println!(
        "E[φ]:    estimated (‖·‖ = {:.4}), from k={} head + l={} tail rows",
        gmips::linalg::norm(&e.mean),
        e.work.k,
        e.work.l
    );

    // 6. accuracy certificate (§4.2.1)
    let top = index.top_k(&theta, cfg.sampler_k());
    let brute = gmips::mips::brute::BruteForce::new(ds.clone(), backend.clone());
    let mut all = vec![0f32; ds.n];
    brute.all_scores(&theta, &mut all);
    let tv = gmips::sampler::tv_bound::tv_bound(&all, &top);
    println!("TV bound for this θ: {tv:.2e} (paper reports ~1e-4 at full scale)");
    Ok(())
}
