//! End-to-end driver (the §4.4 learning experiment, DESIGN.md §End-to-end
//! validation): train a log-linear model by maximum likelihood on a
//! coherent 16-element subset (the "water images" analog), comparing the
//! exact gradient, the top-k-truncated gradient, and Algorithm 4 — with
//! the full three-layer stack on the gradient hot path when artifacts
//! are available (PJRT backend), and the loss curve logged per method.
//!
//!     make artifacts && cargo run --release --example learn_water [-- --pjrt]

use gmips::config::Config;
use gmips::learner::{GradMethod, Learner};
use gmips::prelude::*;
use gmips::runtime::PjrtScorer;
use std::sync::Arc;

fn main() -> Result<()> {
    let use_pjrt = std::env::args().any(|a| a == "--pjrt");

    let mut cfg = Config::preset("imagenet")?;
    cfg.data.n = 30_000;
    cfg.data.d = 64;
    cfg.learn.iters = 400;
    cfg.learn.eval_every = 20;
    cfg.learn.lr = 10.0;
    cfg.learn.lr_halve_every = 80; // paper: halve every 1000 of 5000
    cfg.learn.train_size = 16; // the 16 "water images"
    cfg.learn.k_mult = 10.0; // paper: k = 10√n
    cfg.learn.l_ratio = 10.0; // paper: l = 10k
    cfg.learn.topk_mult = 10.0; // paper: 100√n ≈ 8.8% of n; here 10√n ≈ 5.8%

    let ds = Arc::new(gmips::data::generate(&cfg.data));
    let backend: Arc<dyn ScoreBackend> = if use_pjrt {
        println!("backend: PJRT (AOT artifacts on the gradient hot path)");
        Arc::new(PjrtScorer::load("artifacts")?)
    } else {
        println!("backend: native (pass --pjrt after `make artifacts` for the XLA path)");
        Arc::new(NativeScorer)
    };
    let index = build_index(&ds, &cfg.index, backend.clone())?;
    println!("index: {}", index.describe());

    let learner = Learner::new(ds.clone(), index, backend, cfg.learn.clone())?;
    println!(
        "training set D: {} vectors from one latent cluster (ids {:?}…)\n",
        learner.train_ids.len(),
        &learner.train_ids[..4.min(learner.train_ids.len())]
    );

    let mut results = Vec::new();
    for method in [GradMethod::Exact, GradMethod::TopK, GradMethod::Amortized] {
        let mut rng = Pcg64::new(cfg.learn.seed);
        let res = learner.train(method, &mut rng);
        println!("--- {} gradient ---", method.name());
        println!("loss curve (iter → mean log-likelihood):");
        for p in &res.curve {
            println!("  {:>5}  {:+.4}", p.iter, p.log_likelihood);
        }
        println!(
            "final LL {:+.4} | gradient compute time {:.2}s\n",
            res.final_ll, res.grad_seconds
        );
        results.push(res);
    }

    // Table-2-style summary
    let exact_t = results[0].grad_seconds;
    println!("{:<10} {:>12} {:>10}", "method", "final LL", "speedup");
    for r in &results {
        println!(
            "{:<10} {:>12.4} {:>9.1}x",
            r.method.name(),
            r.final_ll,
            exact_t / r.grad_seconds
        );
    }

    // Figure-6 analog: most probable held-out states under the learned
    // model, and whether they share D's latent cluster
    let best = &results[2];
    let tops = learner.top_samples(&best.theta, 10);
    println!(
        "\ntop-10 most probable held-out states under ours: {:?}\ncluster purity: {:.0}% (Figure 6's 'semantically similar' check)",
        tops,
        learner.cluster_purity(&tops) * 100.0
    );

    // acceptance: ours tracks exact, top-k lags (Table 2's ordering)
    let (exact_ll, topk_ll, ours_ll) =
        (results[0].final_ll, results[1].final_ll, results[2].final_ll);
    assert!(
        (ours_ll - exact_ll).abs() < 0.35,
        "ours should track exact: {ours_ll} vs {exact_ll}"
    );
    assert!(topk_ll <= ours_ll + 0.05, "top-k should not beat ours: {topk_ll} vs {ours_ll}");
    println!("\nend-to-end learning run OK (ordering matches Table 2)");
    Ok(())
}
