//! §4.2.2 — PageRank-style random walk over the dataset, where every
//! transition is one log-linear sampling query with θ = φ(x_t)/τ.
//! The MIPS index is reused across all steps (the amortized setting);
//! the naive chain re-scans the database at every step.
//!
//!     cargo run --release --example random_walk

use gmips::config::Config;
use gmips::prelude::*;
use gmips::walk::RandomWalk;
use std::sync::Arc;

fn main() -> Result<()> {
    let mut cfg = Config::preset("imagenet")?;
    cfg.data.n = 12_000;
    cfg.data.d = 64;
    let steps = 20_000;
    let top = 200;

    let ds = Arc::new(gmips::data::generate(&cfg.data));
    let backend: Arc<dyn ScoreBackend> = Arc::new(NativeScorer);
    let index = build_index(&ds, &cfg.index, backend.clone())?;
    println!("index: {}", index.describe());

    let exact = ExactSampler::new(ds.clone(), backend.clone());
    let ours = LazyGumbelSampler::new(ds.clone(), index, backend.clone(), cfg.sampler_k(), 0.0);
    let walk = RandomWalk::new(ds.clone(), cfg.data.temperature);

    println!("running two {steps}-step chains (exact vs lazy-Gumbel)…");
    let cmp = walk.compare(&exact, &ours, steps, top, 2026);

    println!("\ntop-{top} most-visited overlap:");
    println!("  between chains     : {:.1}%  (paper: 73.6%)", cmp.between_chain * 100.0);
    println!("  within exact chain : {:.1}%  (paper: 69.3%)", cmp.within_exact * 100.0);
    println!("  within ours chain  : {:.1}%  (paper: 72.9%)", cmp.within_approx * 100.0);
    println!(
        "\nwork: exact scanned {} rows total, ours {} ({}x less)",
        cmp.exact_scanned,
        cmp.approx_scanned,
        cmp.exact_scanned / cmp.approx_scanned.max(1)
    );
    println!(
        "chains statistically equivalent: {}",
        cmp.chains_equivalent(0.1)
    );
    Ok(())
}
