//! Serving demo: start the coordinator + TCP server in-process, connect a
//! client, and run a mixed query workload — the paper's amortized
//! inference as a service.
//!
//!     cargo run --release --example serve
//!
//! Config comes from `examples/serve.toml` when present (documenting the
//! sharding / quantization / micro-batching knobs), layered over the
//! `imagenet` preset; without the file the demo falls back to a sharded
//! in-code default.

use gmips::config::toml::TomlDoc;
use gmips::config::Config;
use gmips::coordinator::{Coordinator, Engine, Request, Response};
use gmips::prelude::*;
use gmips::server::{Client, Server};
use std::sync::Arc;

fn main() -> Result<()> {
    let mut cfg = Config::preset("imagenet")?;
    cfg.data.n = 20_000;
    cfg.data.d = 64;
    let toml_path = ["examples/serve.toml", "serve.toml"]
        .into_iter()
        .find(|p| std::path::Path::new(p).exists());
    match toml_path {
        Some(path) => {
            println!("applying {path}");
            cfg.apply_toml(&TomlDoc::load(path)?)?;
        }
        None => {
            // no file: still demo the sharded fan-out
            cfg.index.shards = 4;
        }
    }
    cfg.validate()?;

    println!("building engine (data + index)…");
    let engine = Arc::new(Engine::from_config(&cfg, None)?);
    println!("index: {}", engine.index.describe());
    let ds = engine.ds.clone();
    let coord = Arc::new(Coordinator::start_with_wait(
        engine,
        cfg.serve.workers,
        cfg.serve.queue_depth,
        99,
        cfg.serve.micro_wait_us,
    ));
    let server = Server::bind(coord, "127.0.0.1:0")?;
    let addr = server.local_addr()?;
    println!("server on {addr}");
    let handle = std::thread::spawn(move || server.serve());

    let mut client = Client::connect(&addr)?;
    let mut rng = Pcg64::new(3);

    // mixed workload: the "sequence of related queries" the paper
    // amortizes over — fresh θ per request
    for i in 0..5 {
        let theta = gmips::data::random_theta(&ds, cfg.data.temperature, &mut rng);
        match client.call(&Request::Sample { theta: theta.clone(), count: 3 })? {
            Response::Samples { ids, scanned, tail_m } => {
                println!("req {i}: samples {ids:?} (scanned {scanned}, tail m {tail_m})")
            }
            other => println!("req {i}: unexpected {other:?}"),
        }
        match client.call(&Request::LogPartition { theta })? {
            Response::LogPartition { log_z, k, l } => {
                println!("        log Ẑ = {log_z:.4} (k={k}, l={l})")
            }
            other => println!("        unexpected {other:?}"),
        }
    }

    match client.call(&Request::Stats)? {
        Response::Stats { text, numbers } => {
            println!("\nserver stats:\n{text}");
            println!(
                "structured: cert_hit_rate={:.3} rows/req={:.1} queue_depth={} shed={}",
                numbers.certificate_hit_rate,
                numbers.scanned_rows_per_request,
                numbers.queue_depth,
                numbers.shed
            );
        }
        other => println!("unexpected {other:?}"),
    }

    // Prometheus scrape over the same wire (the `gmips metrics`
    // subcommand does exactly this against a long-running server)
    match client.call(&Request::Metrics)? {
        Response::Metrics { exposition } => {
            let families = exposition.lines().filter(|l| l.starts_with("# TYPE")).count();
            println!("metrics scrape: {families} families");
        }
        other => println!("unexpected {other:?}"),
    }

    client.shutdown_server()?;
    handle.join().unwrap()?;
    println!("server stopped cleanly");
    Ok(())
}
