//! Serving demo: start the coordinator + TCP server in-process, connect a
//! client, and run a mixed query workload — the paper's amortized
//! inference as a service.
//!
//!     cargo run --release --example serve

use gmips::config::Config;
use gmips::coordinator::{Coordinator, Engine, Request, Response};
use gmips::prelude::*;
use gmips::server::{Client, Server};
use std::sync::Arc;

fn main() -> Result<()> {
    let mut cfg = Config::preset("imagenet")?;
    cfg.data.n = 20_000;
    cfg.data.d = 64;

    println!("building engine (data + IVF index)…");
    let engine = Arc::new(Engine::from_config(&cfg, None)?);
    let ds = engine.ds.clone();
    let coord = Arc::new(Coordinator::start(engine, 0, cfg.serve.queue_depth, 99));
    let server = Server::bind(coord, "127.0.0.1:0")?;
    let addr = server.local_addr()?;
    println!("server on {addr}");
    let handle = std::thread::spawn(move || server.serve());

    let mut client = Client::connect(&addr)?;
    let mut rng = Pcg64::new(3);

    // mixed workload: the "sequence of related queries" the paper
    // amortizes over — fresh θ per request
    for i in 0..5 {
        let theta = gmips::data::random_theta(&ds, cfg.data.temperature, &mut rng);
        match client.call(&Request::Sample { theta: theta.clone(), count: 3 })? {
            Response::Samples { ids, scanned, tail_m } => {
                println!("req {i}: samples {ids:?} (scanned {scanned}, tail m {tail_m})")
            }
            other => println!("req {i}: unexpected {other:?}"),
        }
        match client.call(&Request::LogPartition { theta })? {
            Response::LogPartition { log_z, k, l } => {
                println!("        log Ẑ = {log_z:.4} (k={k}, l={l})")
            }
            other => println!("        unexpected {other:?}"),
        }
    }

    match client.call(&Request::Stats)? {
        Response::Stats { text } => println!("\nserver stats:\n{text}"),
        other => println!("unexpected {other:?}"),
    }

    client.shutdown_server()?;
    handle.join().unwrap()?;
    println!("server stopped cleanly");
    Ok(())
}
